//! Simulated network substrate.
//!
//! The paper counts *uplink transmissions* as its efficiency metric;
//! this module counts exactly that, plus per-link bytes and an
//! optional latency/drop model for the failure-injection tests (a
//! capability the paper assumes away — dropped uplinks simply leave
//! the server's aggregate stale, which eq. (5) tolerates by design,
//! and the tests verify it).
//!
//! Two engines consume this module differently: the synchronous
//! [`coordinator`](crate::coordinator) engines use [`LatencyModel`]
//! only for the simulated-wallclock columns, while the asynchronous
//! engine ([`coordinator::async_engine`](crate::coordinator::async_engine))
//! uses it to *order* message deliveries on the [`EventQueue`]'s
//! virtual clock — a slow uplink arrives late and folds stale.

use std::collections::BinaryHeap;

use crate::rng::Xoshiro256;

pub mod downlink;
mod wheel;

pub use downlink::{DownlinkChannel, DownlinkSpec};

/// Per-link accounting.
#[derive(Clone, Copy, Debug, Default)]
pub struct LinkStats {
    /// messages delivered on this link
    pub messages: u64,
    /// payload bytes delivered on this link
    pub bytes: u64,
}

/// Directions from the server's point of view.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Direction {
    /// server → worker (θ broadcast)
    Down,
    /// worker → server (δ∇ upload)
    Up,
}

/// Wire size (bits) of a dense f64 delta of dimension `d`.
///
/// The canonical uplink bit-accounting for uncompressed payloads:
/// 64 bits per coordinate.
pub fn dense_delta_bits(d: usize) -> u64 {
    64 * d as u64
}

/// Wire size (bits) of a sparse delta storing `nnz` coordinates.
///
/// Each kept coordinate is charged a 32-bit index plus a 32-bit (f32)
/// value — the accounting the compressor baselines (top-k
/// sparsification) use, so a sparse payload costs 64·nnz bits instead
/// of 64·d.
pub fn sparse_delta_bits(nnz: usize) -> u64 {
    (32 + 32) * nnz as u64
}

/// Wire size (bits) of a sparse delta whose `nnz` kept values are
/// quantized to `width`-bit levels: a 32-bit (f32) scale header plus,
/// per kept coordinate, a 32-bit index and a `width`-bit value — the
/// honest accounting for the top-k × int-n hybrid codec
/// ([`crate::compress::TopKInt`]), which beats plain top-k's 64·nnz
/// whenever width < 32.
pub fn sparse_packed_delta_bits(width: u32, nnz: usize) -> u64 {
    32 + (32 + u64::from(width)) * nnz as u64
}

/// Wire size (bits) of a bit-packed delta: `width` bits per
/// coordinate plus a per-message `header` (the f32 scale an integer
/// scheme carries; 0 for raw fp32/fp16 fields).
///
/// This charges what the packing actually costs — `width·d`, not
/// `64·d` — so the bits-to-accuracy ledger honestly reflects a
/// packed codec's advantage.
pub fn packed_delta_bits(width: u32, header: u64, d: usize) -> u64 {
    header + u64::from(width) * d as u64
}

/// Latency model: fixed + per-byte cost (the "communication is ~2500×
/// a memory access" premise from the paper's introduction).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LatencyModel {
    /// per-message cost in virtual µs, independent of payload size
    pub fixed_us: f64,
    /// additional virtual µs per KiB of payload
    pub per_kib_us: f64,
}

impl Default for LatencyModel {
    fn default() -> Self {
        // LAN-ish defaults.  Sync engines use these only for the
        // simulated-wallclock columns; the async engine additionally
        // orders message delivery by them, so changing the defaults
        // changes which deltas fold together in async traces.
        Self { fixed_us: 500.0, per_kib_us: 8.0 }
    }
}

impl LatencyModel {
    /// Virtual transfer time (µs) for a `bytes`-sized message.
    pub fn transfer_us(&self, bytes: u64) -> f64 {
        self.fixed_us + self.per_kib_us * (bytes as f64 / 1024.0)
    }

    /// The degenerate model: every transfer takes zero virtual time.
    /// Under it (plus uniform compute) the asynchronous engine's event
    /// order collapses to synchronous rounds — the reduction the
    /// equivalence tests pin.
    pub fn zero() -> Self {
        Self { fixed_us: 0.0, per_kib_us: 0.0 }
    }
}

/// The simulated star network (server + M workers).
pub struct SimNetwork {
    /// per-worker uplink (worker → server) counters
    pub up: Vec<LinkStats>,
    /// per-worker downlink (server → worker) counters
    pub down: Vec<LinkStats>,
    /// transfer-time model for the simulated wallclock / event clock
    pub latency: LatencyModel,
    /// probability an *uplink* message is dropped (failure injection)
    pub drop_prob: f64,
    rng: Xoshiro256,
    /// accumulated simulated wallclock (µs), taking the per-round max
    /// across links (synchronous rounds)
    pub sim_clock_us: f64,
    dropped: u64,
}

impl SimNetwork {
    /// Fresh network for `m_workers` links, no drops, LAN-ish latency.
    pub fn new(m_workers: usize) -> Self {
        Self {
            up: vec![LinkStats::default(); m_workers],
            down: vec![LinkStats::default(); m_workers],
            latency: LatencyModel::default(),
            drop_prob: 0.0,
            rng: Xoshiro256::new(0x5EED_0002),
            sim_clock_us: 0.0,
            dropped: 0,
        }
    }

    /// Enable seeded uplink drops with probability `prob`.
    pub fn with_drops(mut self, prob: f64, seed: u64) -> Self {
        self.drop_prob = prob;
        self.rng = Xoshiro256::new(seed);
        self
    }

    /// Replace the latency model (builder form).
    pub fn with_latency(mut self, latency: LatencyModel) -> Self {
        self.latency = latency;
        self
    }

    /// Record a message; returns false if it was dropped.
    pub fn send(&mut self, dir: Direction, worker: usize, bytes: u64) -> bool {
        let stats = match dir {
            Direction::Down => &mut self.down[worker],
            Direction::Up => &mut self.up[worker],
        };
        if dir == Direction::Up
            && self.drop_prob > 0.0
            && self.rng.next_f64() < self.drop_prob
        {
            self.dropped += 1;
            return false;
        }
        stats.messages += 1;
        stats.bytes += bytes;
        true
    }

    /// Record one round's downlink broadcast: θᵏ goes only to the
    /// scheduled workers (partial participation keeps unscheduled
    /// links silent in both directions).
    pub fn broadcast(&mut self, active: &[bool], bytes: u64) {
        for (id, &scheduled) in active.iter().enumerate() {
            if scheduled {
                self.send(Direction::Down, id, bytes);
            }
        }
    }

    /// Advance the synchronous-round clock: one broadcast down to all
    /// M workers in parallel + the slowest uplink among transmitters.
    pub fn advance_round(&mut self, down_bytes: u64, up_bytes_each: &[u64]) {
        let down = self.latency.transfer_us(down_bytes);
        let up = up_bytes_each
            .iter()
            .map(|&b| self.latency.transfer_us(b))
            .fold(0.0, f64::max);
        self.sim_clock_us += down + up;
    }

    /// Total delivered uplink messages across all workers.
    pub fn total_up_messages(&self) -> u64 {
        self.up.iter().map(|l| l.messages).sum()
    }

    /// Total delivered uplink payload bytes across all workers.
    pub fn total_up_bytes(&self) -> u64 {
        self.up.iter().map(|l| l.bytes).sum()
    }

    /// Total delivered downlink messages across all workers.
    pub fn total_down_messages(&self) -> u64 {
        self.down.iter().map(|l| l.messages).sum()
    }

    /// Uplink messages lost to failure injection.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Raw drop-stream RNG state (checkpoint capture).
    pub fn rng_state(&self) -> [u64; 4] {
        self.rng.state()
    }

    /// Restore the drop stream and the dropped-message counter from a
    /// checkpoint so the next `send` continues bit-identically.
    pub fn restore_state(&mut self, rng: [u64; 4], dropped: u64) {
        self.rng = Xoshiro256::from_state(rng);
        self.dropped = dropped;
    }
}

// ---------------------------------------------------------------------------
// Discrete-event queue (virtual clock)
// ---------------------------------------------------------------------------

/// Priority key of one queued event.
///
/// Events are processed in ascending `(time_us, rank, worker, seq)`
/// order.  `rank` lets a simulation phase deliveries at the *same*
/// virtual instant deterministically (e.g. the async engine delivers
/// broadcasts before compute completions before uplink arrivals), and
/// `seq` is a push-order tiebreaker so the order is total — no f64
/// comparison ever decides between two otherwise-equal events.
#[derive(Clone, Copy, Debug)]
pub struct EventKey {
    /// virtual time of the event (µs)
    pub time_us: f64,
    /// same-instant phase: lower ranks are delivered first
    pub rank: u8,
    /// worker id the event concerns (same-instant, same-rank order)
    pub worker: usize,
    /// push-order sequence number (final tiebreaker)
    pub(crate) seq: u64,
}

impl EventKey {
    /// The push-order sequence number (checkpoint capture).
    pub fn seq(&self) -> u64 {
        self.seq
    }
}

impl EventKey {
    fn cmp_key(&self, other: &Self) -> std::cmp::Ordering {
        self.time_us
            .total_cmp(&other.time_us)
            .then(self.rank.cmp(&other.rank))
            .then(self.worker.cmp(&other.worker))
            .then(self.seq.cmp(&other.seq))
    }
}

struct Entry<T> {
    key: EventKey,
    payload: T,
}

impl<T> PartialEq for Entry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.key.cmp_key(&other.key) == std::cmp::Ordering::Equal
    }
}

impl<T> Eq for Entry<T> {}

impl<T> PartialOrd for Entry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl<T> Ord for Entry<T> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // reversed: BinaryHeap is a max-heap, we want the earliest key
        other.key.cmp_key(&self.key)
    }
}

/// Storage backend of an [`EventQueue`]: the radix timer wheel
/// (default — O(1) amortized, the million-client hot path) or the
/// original global `BinaryHeap` (the reference implementation and the
/// `CHB_FORCE_HEAP` escape hatch).  Both pop in the identical total
/// order, bit for bit.
enum Backend<T> {
    Heap(BinaryHeap<Entry<T>>),
    Wheel(wheel::RadixWheel<T>),
}

/// Deterministic discrete-event queue over a virtual clock.
///
/// The substrate of the asynchronous engine: push events at future
/// virtual times, pop them in deterministic `(time, rank, worker,
/// push-order)` order.  Time never flows backwards — `pop` asserts
/// monotonicity in debug builds.
///
/// Two interchangeable backends sit behind this API: a radix timer
/// wheel (default; O(1) amortized insert/pop, built for ≥10⁶ queued
/// events) and the original global `BinaryHeap`.  They are pinned
/// bit-identical — same pop order under the full `(time, rank,
/// worker, seq)` total order, same checkpoint image — by a property
/// test (`tests/prop_invariants.rs`) and the async-trace equivalence
/// test (`tests/async_engine.rs`).  Setting the `CHB_FORCE_HEAP`
/// environment variable (any non-empty value) makes [`EventQueue::new`]
/// build heap-backed queues, as a production escape hatch.
///
/// ```
/// use chb_fed::net::EventQueue;
/// let mut q = EventQueue::new();
/// q.push(2.0, 0, 7, "late");
/// q.push(1.0, 1, 0, "early-low-priority");
/// q.push(1.0, 0, 3, "early");
/// assert_eq!(q.pop().unwrap().1, "early");
/// assert_eq!(q.pop().unwrap().1, "early-low-priority");
/// assert_eq!(q.pop().unwrap().1, "late");
/// assert!(q.pop().is_none());
/// ```
pub struct EventQueue<T> {
    backend: Backend<T>,
    seq: u64,
    last_popped_us: f64,
}

impl<T> Default for EventQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

/// Is the `CHB_FORCE_HEAP` escape hatch set?  (Checked once per queue
/// construction; an empty value counts as unset, mirroring
/// `CHB_FORCE_SCALAR` in the SIMD layer.)
fn force_heap() -> bool {
    std::env::var_os("CHB_FORCE_HEAP").is_some_and(|v| !v.is_empty())
}

impl<T> EventQueue<T> {
    /// Empty queue at virtual time 0 on the default backend (the
    /// radix wheel, unless `CHB_FORCE_HEAP` is set).
    pub fn new() -> Self {
        if force_heap() {
            Self::with_heap()
        } else {
            Self::with_wheel()
        }
    }

    /// Empty queue on the `BinaryHeap` backend (tests + escape hatch).
    pub fn with_heap() -> Self {
        Self {
            backend: Backend::Heap(BinaryHeap::new()),
            seq: 0,
            last_popped_us: 0.0,
        }
    }

    /// Empty queue on the radix-wheel backend (tests pin this against
    /// [`EventQueue::with_heap`] bit for bit).
    pub fn with_wheel() -> Self {
        Self {
            backend: Backend::Wheel(wheel::RadixWheel::new()),
            seq: 0,
            last_popped_us: 0.0,
        }
    }

    /// Which backend this queue runs on ("wheel" / "heap") — for
    /// logs and tests only; behavior is identical by contract.
    pub fn backend_name(&self) -> &'static str {
        match self.backend {
            Backend::Heap(_) => "heap",
            Backend::Wheel(_) => "wheel",
        }
    }

    /// Schedule `payload` at virtual time `time_us` with phase `rank`
    /// for `worker`.  Times must be finite and non-negative.
    pub fn push(&mut self, time_us: f64, rank: u8, worker: usize, payload: T) {
        assert!(
            time_us.is_finite() && time_us >= 0.0,
            "event time must be finite and ≥ 0, got {time_us}"
        );
        let key = EventKey { time_us, rank, worker, seq: self.seq };
        self.seq += 1;
        match &mut self.backend {
            Backend::Heap(h) => h.push(Entry { key, payload }),
            Backend::Wheel(w) => w.push(Entry { key, payload }),
        }
    }

    /// Remove and return the earliest event.
    pub fn pop(&mut self) -> Option<(EventKey, T)> {
        let e = match &mut self.backend {
            Backend::Heap(h) => h.pop()?,
            Backend::Wheel(w) => w.pop()?,
        };
        debug_assert!(
            e.key.time_us >= self.last_popped_us,
            "virtual clock went backwards"
        );
        self.last_popped_us = e.key.time_us;
        Some((e.key, e.payload))
    }

    /// Key of the earliest event without removing it.
    pub fn peek(&self) -> Option<&EventKey> {
        match &self.backend {
            Backend::Heap(h) => h.peek().map(|e| &e.key),
            Backend::Wheel(w) => w.peek(),
        }
    }

    /// Number of queued events.
    pub fn len(&self) -> usize {
        match &self.backend {
            Backend::Heap(h) => h.len(),
            Backend::Wheel(w) => w.len(),
        }
    }

    /// Is the queue empty?
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drain the queue, yielding remaining events in order (used by
    /// the async engine to account for in-flight messages at exit).
    pub fn drain_ordered(&mut self) -> Vec<(EventKey, T)> {
        let mut out = Vec::with_capacity(self.len());
        while let Some(e) = self.pop() {
            out.push(e);
        }
        out
    }

    /// Non-destructive ordered view of every queued event (checkpoint
    /// capture): entries sorted by the total `(time, rank, worker,
    /// seq)` order, with their exact keys.  Backend-independent, so a
    /// wheel-backed queue checkpoints byte-identically to a
    /// heap-backed one.
    pub fn entries_ordered(&self) -> Vec<(EventKey, &T)> {
        let mut out: Vec<(EventKey, &T)> = match &self.backend {
            Backend::Heap(h) => {
                h.iter().map(|e| (e.key, &e.payload)).collect()
            }
            Backend::Wheel(w) => w.iter().map(|(k, p)| (*k, p)).collect(),
        };
        out.sort_by(|a, b| a.0.cmp_key(&b.0));
        out
    }

    /// Internal counters `(next seq, last popped time)` — captured
    /// alongside [`EventQueue::entries_ordered`] so a restored queue
    /// assigns the same tiebreaker sequence to future pushes.
    pub fn counters(&self) -> (u64, f64) {
        (self.seq, self.last_popped_us)
    }

    /// Rebuild a queue from captured entries (with their original
    /// keys, including `seq`) and counters.  The restored queue pops
    /// in exactly the order the original would have, on the default
    /// backend — checkpoints carry no backend identity, so a PR 7
    /// image written by a heap-backed run restores onto the wheel
    /// (and vice versa under `CHB_FORCE_HEAP`) unchanged.
    pub fn restore(
        entries: Vec<(EventKey, T)>,
        seq: u64,
        last_popped_us: f64,
    ) -> Self {
        let mut backend = if force_heap() {
            Backend::Heap(BinaryHeap::with_capacity(entries.len()))
        } else {
            Backend::Wheel(wheel::RadixWheel::anchored_at(last_popped_us))
        };
        for (key, payload) in entries {
            match &mut backend {
                Backend::Heap(h) => h.push(Entry { key, payload }),
                Backend::Wheel(w) => w.push(Entry { key, payload }),
            }
        }
        Self { backend, seq, last_popped_us }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delta_bit_model_charges_index_plus_value_for_sparse() {
        assert_eq!(dense_delta_bits(784), 64 * 784);
        assert_eq!(sparse_delta_bits(25), 64 * 25);
        assert_eq!(sparse_delta_bits(0), 0);
        // sparse beats dense whenever fewer than d coordinates are kept
        assert!(sparse_delta_bits(25) < dense_delta_bits(784));
    }

    #[test]
    fn sparse_packed_bits_charge_header_index_and_width() {
        assert_eq!(sparse_packed_delta_bits(8, 25), 32 + 40 * 25);
        assert_eq!(sparse_packed_delta_bits(8, 0), 32);
        // quantizing the kept values beats plain top-k for width < 32
        assert!(sparse_packed_delta_bits(8, 100) < sparse_delta_bits(100));
    }

    #[test]
    fn packed_bits_charge_width_plus_header() {
        assert_eq!(packed_delta_bits(32, 0, 784), 32 * 784); // fp32
        assert_eq!(packed_delta_bits(16, 0, 784), 16 * 784); // fp16
        assert_eq!(packed_delta_bits(8, 32, 784), 32 + 8 * 784); // int8
        // int8 with its scale header stays ≤ 1/4 of the dense cost at
        // realistic dimensions — the ladder's headline ratio
        assert!(4 * packed_delta_bits(8, 32, 784) <= dense_delta_bits(784));
    }

    #[test]
    fn counts_up_and_down_separately() {
        let mut n = SimNetwork::new(2);
        assert!(n.send(Direction::Down, 0, 100));
        assert!(n.send(Direction::Up, 0, 50));
        assert!(n.send(Direction::Up, 1, 50));
        assert_eq!(n.total_down_messages(), 1);
        assert_eq!(n.total_up_messages(), 2);
        assert_eq!(n.total_up_bytes(), 100);
    }

    #[test]
    fn drops_are_uplink_only_and_counted() {
        let mut n = SimNetwork::new(1).with_drops(1.0, 7);
        assert!(n.send(Direction::Down, 0, 10)); // downlink never drops
        assert!(!n.send(Direction::Up, 0, 10));
        assert_eq!(n.dropped(), 1);
        assert_eq!(n.total_up_messages(), 0);
    }

    #[test]
    fn broadcast_skips_unscheduled_workers() {
        let mut n = SimNetwork::new(3);
        n.broadcast(&[true, false, true], 100);
        assert_eq!(n.total_down_messages(), 2);
        assert_eq!(n.down[0].bytes, 100);
        assert_eq!(n.down[1].messages, 0);
        assert_eq!(n.down[2].bytes, 100);
    }

    #[test]
    fn round_clock_takes_max_uplink() {
        let mut n = SimNetwork::new(3);
        n.latency = LatencyModel { fixed_us: 100.0, per_kib_us: 0.0 };
        n.advance_round(1024, &[10, 10, 10]);
        // down 100 + slowest up 100
        assert!((n.sim_clock_us - 200.0).abs() < 1e-9);
        n.advance_round(0, &[]);
        // no uplinks this round: just the broadcast
        assert!((n.sim_clock_us - 300.0).abs() < 1e-9);
    }

    #[test]
    fn latency_model_scales_with_bytes() {
        let l = LatencyModel { fixed_us: 1.0, per_kib_us: 2.0 };
        assert!((l.transfer_us(2048) - 5.0).abs() < 1e-12);
        assert_eq!(LatencyModel::zero().transfer_us(1 << 20), 0.0);
    }

    #[test]
    fn event_queue_orders_by_time_rank_worker_seq() {
        let mut q = EventQueue::new();
        q.push(5.0, 0, 0, "t5");
        q.push(1.0, 2, 9, "t1-rank2");
        q.push(1.0, 0, 4, "t1-rank0-w4");
        q.push(1.0, 0, 2, "t1-rank0-w2");
        q.push(1.0, 0, 2, "t1-rank0-w2-later");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop())
            .map(|(_, p)| p)
            .collect();
        assert_eq!(
            order,
            vec![
                "t1-rank0-w2",
                "t1-rank0-w2-later",
                "t1-rank0-w4",
                "t1-rank2",
                "t5"
            ]
        );
    }

    #[test]
    fn event_queue_peek_and_drain() {
        let mut q = EventQueue::new();
        assert!(q.is_empty());
        q.push(3.0, 1, 0, 30);
        q.push(2.0, 0, 1, 20);
        assert_eq!(q.len(), 2);
        let k = q.peek().unwrap();
        assert_eq!((k.time_us, k.rank, k.worker), (2.0, 0, 1));
        let drained = q.drain_ordered();
        assert_eq!(
            drained.iter().map(|(_, p)| *p).collect::<Vec<_>>(),
            vec![20, 30]
        );
        assert!(q.is_empty());
    }

    #[test]
    #[should_panic(expected = "finite")]
    fn event_queue_rejects_nan_times() {
        EventQueue::new().push(f64::NAN, 0, 0, ());
    }
}
