//! The downlink channel: server → worker broadcasts are no longer
//! free.
//!
//! The paper charges only uplink transmissions; a deployment pays for
//! both directions.  This module makes the broadcast a first-class
//! channel: every engine *accounts* downlink bits (64·d per scheduled
//! worker per round when uncompressed — the `downlink_bits_cum` trace
//! column), and the sync engines can additionally *compress* the
//! broadcast through the same codec stack as the uplink
//! ([`crate::compress`]), with optional server-side error feedback.
//!
//! Compression works on the model-delta stream: the server keeps a
//! shared *view* θ̃ᵏ — the decoded iterate every worker holds — and
//! each round encodes δ = θᵏ − θ̃ᵏ, folds the decode back into the
//! view, and broadcasts the view.  Workers therefore all see the same
//! (slightly stale) iterate, censor against the view's step ‖θ̃ᵏ −
//! θ̃^{k−1}‖², and eq. (5)'s telescoping aggregate is untouched — the
//! compression error enters as server-side iterate staleness, exactly
//! dual to how uplink codecs enter as gradient staleness.  The first
//! broadcast is the full-precision model sync (charged dense), so the
//! view starts exact.
//!
//! With [`DownlinkSpec::None`] the channel is pass-through: the
//! broadcast carries θᵏ itself and is charged
//! [`dense_delta_bits`]`(d)` — runs are bit-identical to the
//! pre-downlink code (pinned in `tests/engine_equivalence.rs`).

use std::sync::Arc;

use crate::compress::{
    CodecScratch, Compressor, ErrorFeedback, PackedFp16, PackedFp32,
    PackedInt, Payload,
};
use crate::linalg;

use super::dense_delta_bits;

/// The downlink-compression axis of a run spec.  `None` keeps the
/// broadcast uncompressed (accounting only — the legacy-bitwise
/// setting); the rest route the broadcast delta through the packed
/// codec stack with optional server-side error feedback.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DownlinkSpec {
    /// uncompressed θ broadcast, charged 64 bits/coordinate
    None,
    /// f32 bit patterns (32 bits/coordinate)
    Fp32 {
        /// carry the narrowing error into the next broadcast
        error_feedback: bool,
    },
    /// IEEE half precision (16 bits/coordinate)
    Fp16 {
        /// carry the rounding error into the next broadcast
        error_feedback: bool,
    },
    /// bit-packed `bits`-wide uniform levels + f32 scale header
    Int {
        /// bits per coordinate (2..=32)
        bits: u32,
        /// carry the quantization error into the next broadcast
        error_feedback: bool,
    },
}

impl DownlinkSpec {
    /// Spec-file name of the variant.
    pub fn name(&self) -> &'static str {
        match self {
            DownlinkSpec::None => "none",
            DownlinkSpec::Fp32 { .. } => "fp32",
            DownlinkSpec::Fp16 { .. } => "fp16",
            DownlinkSpec::Int { .. } => "int",
        }
    }

    /// Is this the pass-through (accounting-only) channel?
    pub fn is_none(&self) -> bool {
        *self == DownlinkSpec::None
    }

    /// Materialize the broadcast codec (None for pass-through).
    pub fn build_codec(&self) -> Option<Box<dyn Compressor>> {
        match *self {
            DownlinkSpec::None => None,
            DownlinkSpec::Fp32 { error_feedback: false } => {
                Some(Box::new(PackedFp32))
            }
            DownlinkSpec::Fp32 { error_feedback: true } => {
                Some(Box::new(ErrorFeedback(PackedFp32)))
            }
            DownlinkSpec::Fp16 { error_feedback: false } => {
                Some(Box::new(PackedFp16))
            }
            DownlinkSpec::Fp16 { error_feedback: true } => {
                Some(Box::new(ErrorFeedback(PackedFp16)))
            }
            DownlinkSpec::Int { bits, error_feedback: false } => {
                Some(Box::new(PackedInt { bits }))
            }
            DownlinkSpec::Int { bits, error_feedback: true } => {
                Some(Box::new(ErrorFeedback(PackedInt { bits })))
            }
        }
    }
}

/// Simulated framing of one broadcast: payload bits rounded up to
/// bytes, plus the 16-byte header [`crate::coordinator::protocol::
/// broadcast_bytes`] charges (step_sq + round index).  For the
/// uncompressed channel this is exactly `broadcast_bytes(d)` = 8d+16,
/// so the sim-clock columns are unchanged under `downlink = none`.
pub fn downlink_frame_bytes(bits: u64) -> u64 {
    bits.div_ceil(8) + 16
}

/// Server-side state of the broadcast channel: the codec (if any),
/// its scratch/error-feedback residual, and the shared worker view.
pub struct DownlinkChannel {
    codec: Option<Box<dyn Compressor>>,
    scratch: CodecScratch,
    payload: Payload,
    view: Vec<f64>,
    prev_view: Vec<f64>,
    delta: Vec<f64>,
    initialized: bool,
}

impl DownlinkChannel {
    /// Channel for `spec` (pass-through when `spec` is `None`).
    pub fn new(spec: DownlinkSpec) -> Self {
        Self {
            codec: spec.build_codec(),
            scratch: CodecScratch::default(),
            payload: Payload::default(),
            view: Vec::new(),
            prev_view: Vec::new(),
            delta: Vec::new(),
            initialized: false,
        }
    }

    /// Is this channel actually compressing (vs. accounting only)?
    pub fn is_compressing(&self) -> bool {
        self.codec.is_some()
    }

    /// Encode one round's broadcast.  Returns `(view, view_step_sq,
    /// bits)`: the iterate workers receive, the censor step reference
    /// ‖θ̃ᵏ − θ̃^{k−1}‖² matching it, and the charged payload bits for
    /// one worker's downlink.
    ///
    /// Pass-through channels return `theta` itself, `step_sq`
    /// unchanged, and the dense charge — bit-identical to the
    /// pre-downlink broadcast.
    pub fn encode(
        &mut self,
        theta: &[f64],
        step_sq: f64,
    ) -> (Arc<Vec<f64>>, f64, u64) {
        let d = theta.len();
        let Some(codec) = &self.codec else {
            return (Arc::new(theta.to_vec()), step_sq, dense_delta_bits(d));
        };
        if !self.initialized {
            // round 0: full-precision model sync — view starts exact
            self.initialized = true;
            self.view.clear();
            self.view.extend_from_slice(theta);
            self.prev_view.clear();
            self.prev_view.extend_from_slice(theta);
            self.delta.resize(d, 0.0);
            return (Arc::new(self.view.clone()), step_sq, dense_delta_bits(d));
        }
        // δ = θᵏ − θ̃^{k−1}; compress, then fold the *decode* into the
        // view so server and workers track the same iterate
        linalg::sub_into(theta, &self.view, &mut self.delta);
        let bits =
            codec.compress_into(&self.delta, &mut self.scratch, &mut self.payload);
        self.prev_view.copy_from_slice(&self.view);
        self.payload.fold_into(&mut self.view);
        let view_step_sq: f64 = self
            .view
            .iter()
            .zip(&self.prev_view)
            .map(|(a, b)| (a - b) * (a - b))
            .sum();
        (Arc::new(self.view.clone()), view_step_sq, bits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passthrough_channel_is_identity() {
        let mut ch = DownlinkChannel::new(DownlinkSpec::None);
        assert!(!ch.is_compressing());
        let theta = vec![1.5, -2.0, 0.25];
        let (view, sq, bits) = ch.encode(&theta, 7.5);
        assert_eq!(*view, theta);
        assert_eq!(sq, 7.5);
        assert_eq!(bits, dense_delta_bits(3));
        assert_eq!(downlink_frame_bytes(bits), (3 * 8 + 16) as u64);
    }

    #[test]
    fn first_compressed_broadcast_is_exact_dense_sync() {
        let spec = DownlinkSpec::Int { bits: 8, error_feedback: true };
        let mut ch = DownlinkChannel::new(spec);
        assert!(ch.is_compressing());
        let theta = vec![0.5, -0.25, 3.0, 0.0];
        let (view, sq, bits) = ch.encode(&theta, 0.0);
        for (a, b) in theta.iter().zip(view.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(sq, 0.0);
        assert_eq!(bits, dense_delta_bits(4));
    }

    #[test]
    fn compressed_view_tracks_theta_within_codec_error() {
        let spec = DownlinkSpec::Int { bits: 8, error_feedback: true };
        let mut ch = DownlinkChannel::new(spec);
        let d = 16;
        let mut theta = vec![0.0; d];
        let mut rng = crate::rng::Xoshiro256::new(0xD0FF);
        ch.encode(&theta, 0.0);
        for _ in 0..50 {
            for t in theta.iter_mut() {
                *t += 0.05 * rng.next_gaussian();
            }
            let (view, sq, bits) = ch.encode(&theta, 1.0);
            assert!(sq.is_finite() && sq >= 0.0);
            // int8 payload: 32-bit header + 8 bits/coordinate
            assert_eq!(bits, 32 + 8 * d as u64);
            let err: f64 = view
                .iter()
                .zip(&theta)
                .map(|(a, b)| (a - b) * (a - b))
                .sum();
            assert!(err < 1e-2, "view diverged from theta: {err}");
        }
    }

    #[test]
    fn fp32_roundtrip_view_is_near_exact() {
        let mut ch =
            DownlinkChannel::new(DownlinkSpec::Fp32 { error_feedback: false });
        let theta0 = vec![1.0, 2.0];
        ch.encode(&theta0, 0.0);
        let theta1 = vec![1.5, 2.25]; // f32-exact deltas
        let (view, _, bits) = ch.encode(&theta1, 0.0);
        assert_eq!(bits, 32 * 2);
        for (a, b) in view.iter().zip(&theta1) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn codec_table_matches_spec() {
        assert!(DownlinkSpec::None.build_codec().is_none());
        assert!(DownlinkSpec::None.is_none());
        for (spec, want) in [
            (DownlinkSpec::Fp32 { error_feedback: false }, "fp32"),
            (DownlinkSpec::Fp16 { error_feedback: false }, "fp16"),
            (DownlinkSpec::Int { bits: 8, error_feedback: false }, "int"),
        ] {
            assert_eq!(spec.build_codec().unwrap().name(), spec.name());
            assert_eq!(spec.name(), want);
        }
        for spec in [
            DownlinkSpec::Fp32 { error_feedback: true },
            DownlinkSpec::Fp16 { error_feedback: true },
            DownlinkSpec::Int { bits: 4, error_feedback: true },
        ] {
            assert_eq!(
                spec.build_codec().unwrap().name(),
                "error-feedback"
            );
        }
    }
}
