//! Radix timer wheel: the O(1)-amortized backend of
//! [`super::EventQueue`].
//!
//! A global `BinaryHeap` costs O(log n) per operation with n = every
//! scheduled event — at 10⁶ simulated clients that is both the pop
//! constant and a cache miss per level.  This wheel is a 64-level
//! radix structure over a monotone integer image of the event time:
//! insert and pop are O(1) amortized (each entry moves between levels
//! at most 64 times over its lifetime), and the hot path touches one
//! small bucket instead of a tree of the whole horizon.
//!
//! **Bit-identical contract.**  The wheel pops in *exactly* the order
//! the heap backend does — the full `(time_us, rank, worker, seq)`
//! total order of [`super::EventKey::cmp_key`], including same-instant
//! batches and `-0.0`/denormal times.  `tests/prop_invariants.rs`
//! pins wheel ≡ heap bitwise over random workloads, and
//! `CHB_FORCE_HEAP=1` re-runs any engine on the heap backend as an
//! escape hatch.
//!
//! Mechanics: times map through [`time_key`], an order-preserving
//! `f64 → u64` bijection (matches `f64::total_cmp`).  The wheel keeps
//! an anchor `last` (the key of the most recent redistribution).
//! Entries with key ≤ anchor live in a small fully-ordered front heap
//! (same-instant batches, and — defensively — any time regression the
//! heap backend would also have tolerated); entries with key > anchor
//! live in level `msb(key XOR anchor)`, the classic radix-heap rule.
//! Popping drains the front; when it empties, the lowest occupied
//! level is redistributed around its minimum key, which becomes the
//! new anchor.  Anchor advances never invalidate higher levels
//! (entries there still first differ from the new anchor at the same
//! bit), which is what makes the per-entry move count ≤ 64.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::{Entry, EventKey};

/// Order-preserving `f64 → u64` key map: `a.total_cmp(&b) ==
/// time_key(a).cmp(&time_key(b))` for every pair, including NaN
/// payloads, infinities, and `-0.0 < +0.0`.
#[inline]
pub(super) fn time_key(t: f64) -> u64 {
    let b = t.to_bits();
    if b >> 63 == 1 {
        !b
    } else {
        b | (1 << 63)
    }
}

/// Min-ordered wrapper so the front `BinaryHeap` (a max-heap) pops
/// the earliest full key first — the same reversal the heap backend
/// uses.
struct FrontEntry<T>(Entry<T>);

impl<T> PartialEq for FrontEntry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.0.key.cmp_key(&other.0.key) == Ordering::Equal
    }
}

impl<T> Eq for FrontEntry<T> {}

impl<T> PartialOrd for FrontEntry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<T> Ord for FrontEntry<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        other.0.key.cmp_key(&self.0.key)
    }
}

/// The 64-level radix wheel.  See the module docs for the invariants.
pub(super) struct RadixWheel<T> {
    /// fully-ordered entries at keys ≤ `anchor` (same-instant batch)
    front: BinaryHeap<FrontEntry<T>>,
    /// level ℓ holds entries whose key first differs from `anchor` at
    /// bit ℓ (unsorted — order is recovered at redistribution)
    levels: Vec<Vec<Entry<T>>>,
    /// occupancy bitmask: bit ℓ set ⇔ `levels[ℓ]` is non-empty
    occupied: u64,
    /// the radix anchor (a [`time_key`] image)
    anchor: u64,
    /// total entries across front + levels
    len: usize,
}

impl<T> RadixWheel<T> {
    /// Empty wheel anchored at virtual time 0.
    pub(super) fn new() -> Self {
        Self::anchored_at(0.0)
    }

    /// Empty wheel anchored at `time_us` (checkpoint restore: the
    /// restored queue resumes with the original's popped-time
    /// watermark, so every live entry lands in the same level
    /// structure a freshly-replayed queue would build).
    pub(super) fn anchored_at(time_us: f64) -> Self {
        Self {
            front: BinaryHeap::new(),
            levels: (0..64).map(|_| Vec::new()).collect(),
            occupied: 0,
            anchor: time_key(time_us),
            len: 0,
        }
    }

    #[inline]
    pub(super) fn len(&self) -> usize {
        self.len
    }

    /// Level of `key` relative to the current anchor, or `None` for
    /// keys at or before it (those are front entries).
    #[inline]
    fn level_of(&self, key: u64) -> Option<usize> {
        if key <= self.anchor {
            None
        } else {
            Some(63 - (key ^ self.anchor).leading_zeros() as usize)
        }
    }

    #[inline]
    fn place(&mut self, e: Entry<T>) {
        match self.level_of(time_key(e.key.time_us)) {
            None => self.front.push(FrontEntry(e)),
            Some(l) => {
                self.levels[l].push(e);
                self.occupied |= 1 << l;
            }
        }
    }

    pub(super) fn push(&mut self, e: Entry<T>) {
        self.place(e);
        self.len += 1;
    }

    /// Drain the lowest occupied level around its minimum key, which
    /// becomes the new anchor.  Entries at the minimum key fall into
    /// the front (fully ordered there); the rest re-place into
    /// strictly lower levels.  Only called with an empty front and a
    /// non-empty wheel.
    fn redistribute(&mut self) {
        debug_assert!(self.front.is_empty() && self.occupied != 0);
        let l = self.occupied.trailing_zeros() as usize;
        let drained = std::mem::take(&mut self.levels[l]);
        self.occupied &= !(1 << l);
        let new_anchor = drained
            .iter()
            .map(|e| time_key(e.key.time_us))
            .min()
            .expect("occupied level is non-empty");
        self.anchor = new_anchor;
        for e in drained {
            self.place(e);
        }
    }

    pub(super) fn pop(&mut self) -> Option<Entry<T>> {
        if self.len == 0 {
            return None;
        }
        if self.front.is_empty() {
            self.redistribute();
        }
        let e = self.front.pop().expect("redistribute fills the front").0;
        self.len -= 1;
        Some(e)
    }

    /// Earliest key without removing it.  Front hits are O(1); with an
    /// empty front this scans the lowest occupied level (no `&mut`, so
    /// no redistribution) — fine for the engines, which only peek
    /// while consuming a same-instant batch already in the front.
    pub(super) fn peek(&self) -> Option<&EventKey> {
        if let Some(e) = self.front.peek() {
            return Some(&e.0.key);
        }
        if self.occupied == 0 {
            return None;
        }
        let l = self.occupied.trailing_zeros() as usize;
        self.levels[l]
            .iter()
            .min_by(|a, b| a.key.cmp_key(&b.key))
            .map(|e| &e.key)
    }

    /// Every live entry, unordered (checkpoint capture sorts).
    pub(super) fn iter(&self) -> impl Iterator<Item = (&EventKey, &T)> {
        self.front
            .iter()
            .map(|e| (&e.0.key, &e.0.payload))
            .chain(
                self.levels
                    .iter()
                    .flat_map(|lv| lv.iter().map(|e| (&e.key, &e.payload))),
            )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_key_is_monotone_over_tricky_floats() {
        let xs = [
            f64::NEG_INFINITY,
            -1.0e300,
            -2.5,
            -f64::MIN_POSITIVE,
            -0.0,
            0.0,
            f64::MIN_POSITIVE,
            1e-300,
            1.0,
            2.5,
            1.0e300,
            f64::INFINITY,
        ];
        for (i, &a) in xs.iter().enumerate() {
            for &b in &xs[i..] {
                assert_eq!(
                    a.total_cmp(&b),
                    time_key(a).cmp(&time_key(b)),
                    "{a} vs {b}"
                );
            }
        }
        // -0.0 and +0.0 are distinct keys in total_cmp order
        assert!(time_key(-0.0) < time_key(0.0));
    }

    fn key(t: f64, rank: u8, worker: usize, seq: u64) -> EventKey {
        EventKey { time_us: t, rank, worker, seq }
    }

    #[test]
    fn wheel_pops_in_full_total_order() {
        let mut w = RadixWheel::new();
        let keys = [
            key(5.0, 0, 0, 0),
            key(1.0, 2, 9, 1),
            key(1.0, 0, 4, 2),
            key(1.0, 0, 2, 3),
            key(1.0, 0, 2, 4),
            key(0.0, 1, 0, 5),
            key(1e9, 0, 0, 6),
            key(5.0, 0, 0, 7),
        ];
        for (i, &k) in keys.iter().enumerate() {
            w.push(Entry { key: k, payload: i });
        }
        let mut sorted = keys.to_vec();
        sorted.sort_by(|a, b| a.cmp_key(b));
        let mut got = Vec::new();
        while let Some(e) = w.pop() {
            got.push(e.key);
        }
        assert_eq!(got.len(), sorted.len());
        for (g, s) in got.iter().zip(&sorted) {
            assert_eq!(g.cmp_key(s), Ordering::Equal);
        }
    }

    #[test]
    fn interleaved_push_pop_stays_ordered() {
        let mut w = RadixWheel::new();
        let mut seq = 0u64;
        let mut push = |w: &mut RadixWheel<u64>, t: f64| {
            w.push(Entry { key: key(t, 0, 0, seq), payload: seq });
            seq += 1;
        };
        push(&mut w, 10.0);
        push(&mut w, 3.0);
        assert_eq!(w.pop().unwrap().key.time_us, 3.0);
        // pushes at/after the advanced anchor, including one exactly at
        // the last popped instant
        push(&mut w, 3.0);
        push(&mut w, 7.0);
        assert_eq!(w.pop().unwrap().key.time_us, 3.0);
        assert_eq!(w.pop().unwrap().key.time_us, 7.0);
        assert_eq!(w.pop().unwrap().key.time_us, 10.0);
        assert!(w.pop().is_none());
        assert_eq!(w.len(), 0);
    }

    #[test]
    fn peek_agrees_with_pop_without_mutating() {
        let mut w = RadixWheel::new();
        for (i, t) in [4.0, 2.0, 2.0, 8.0].iter().enumerate() {
            w.push(Entry { key: key(*t, 0, i, i as u64), payload: i });
        }
        while w.len() > 0 {
            let peeked = *w.peek().unwrap();
            let popped = w.pop().unwrap().key;
            assert_eq!(peeked.cmp_key(&popped), Ordering::Equal);
        }
        assert!(w.peek().is_none());
    }
}
