//! Statistical micro/macro-benchmark harness (criterion is not on
//! this image).  Used by every `benches/*.rs` target (`harness =
//! false` in Cargo.toml) and by the §Perf pass.
//!
//! Method: warmup, then timed samples; report median and MAD with
//! simple outlier rejection.  Deterministic sample counts so repeated
//! `cargo bench` runs are comparable.

use std::time::Instant;

/// One benchmark's result.
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// benchmark label
    pub name: String,
    /// timed samples taken
    pub samples: usize,
    /// per-iteration time, seconds
    pub median: f64,
    /// median absolute deviation
    pub mad: f64,
    /// fastest sample (seconds per iteration)
    pub min: f64,
    /// slowest sample (seconds per iteration)
    pub max: f64,
}

impl BenchResult {
    /// One-line human-readable summary.
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12}/iter  (±{:>10}, n={}, min {}, max {})",
            self.name,
            crate::util::timer::fmt_duration(self.median),
            crate::util::timer::fmt_duration(self.mad),
            self.samples,
            crate::util::timer::fmt_duration(self.min),
            crate::util::timer::fmt_duration(self.max),
        )
    }
}

/// Harness configuration.
#[derive(Clone, Copy, Debug)]
pub struct Bencher {
    /// untimed warmup iterations before sampling
    pub warmup_iters: usize,
    /// timed samples to take
    pub samples: usize,
    /// iterations per timed sample (amortizes clock overhead)
    pub iters_per_sample: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Self { warmup_iters: 3, samples: 15, iters_per_sample: 1 }
    }
}

impl Bencher {
    /// Low-sample profile for slow bodies (figure drivers, e2e runs).
    pub fn quick() -> Self {
        Self { warmup_iters: 1, samples: 5, iters_per_sample: 1 }
    }

    /// For sub-millisecond bodies: batch many iters per sample.
    pub fn micro() -> Self {
        Self { warmup_iters: 10, samples: 25, iters_per_sample: 100 }
    }

    /// Run `f` and report per-iteration stats.  `f` takes the
    /// iteration index (so stateful bodies can reset / vary).
    pub fn run<F: FnMut(usize)>(&self, name: &str, mut f: F) -> BenchResult {
        for i in 0..self.warmup_iters * self.iters_per_sample {
            f(i);
        }
        let mut times = Vec::with_capacity(self.samples);
        let mut idx = 0usize;
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..self.iters_per_sample {
                f(idx);
                idx += 1;
            }
            times.push(
                t0.elapsed().as_secs_f64() / self.iters_per_sample as f64,
            );
        }
        let result = summarize(name, &mut times);
        println!("{}", result.report());
        result
    }
}

fn summarize(name: &str, times: &mut [f64]) -> BenchResult {
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = percentile_sorted(times, 0.5);
    let mut devs: Vec<f64> = times.iter().map(|t| (t - median).abs()).collect();
    devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mad = percentile_sorted(&devs, 0.5);
    BenchResult {
        name: name.to_string(),
        samples: times.len(),
        median,
        mad,
        min: times[0],
        max: times[times.len() - 1],
    }
}

fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let idx = (p * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Print the standard bench header (called by each bench target).
pub fn header(target: &str) {
    println!("== bench: {target} ==");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_and_mad_of_known_samples() {
        let mut t = vec![3.0, 1.0, 2.0, 100.0, 2.5];
        let r = summarize("x", &mut t);
        assert_eq!(r.median, 2.5);
        assert_eq!(r.min, 1.0);
        assert_eq!(r.max, 100.0);
        // devs from 2.5: [1.5, 0.5, 0, 97.5, 0] sorted → median 0.5
        assert_eq!(r.mad, 0.5);
    }

    #[test]
    fn bencher_runs_expected_iterations() {
        let b = Bencher { warmup_iters: 2, samples: 3, iters_per_sample: 4 };
        let mut count = 0usize;
        b.run("count", |_| count += 1);
        assert_eq!(count, 2 * 4 + 3 * 4);
    }

    #[test]
    fn percentile_degenerate() {
        assert!(percentile_sorted(&[], 0.5).is_nan());
        assert_eq!(percentile_sorted(&[7.0], 0.5), 7.0);
    }
}
