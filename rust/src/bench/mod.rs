//! Statistical micro/macro-benchmark harness (criterion is not on
//! this image).  Used by every `benches/*.rs` target (`harness =
//! false` in Cargo.toml) and by the §Perf pass.
//!
//! Method: warmup, then timed samples; report median and MAD with
//! simple outlier rejection.  Deterministic sample counts so repeated
//! `cargo bench` runs are comparable.
//!
//! Beyond the stdout report, [`write_json`] emits the machine-readable
//! `BENCH_<target>.json` (name / median_ns / mad_ns / iters per entry)
//! that pins the perf trajectory PR-over-PR — CI runs the `hotpath`
//! target in `--smoke` mode and uploads the file as an artifact.

use std::path::Path;
use std::time::Instant;

/// One benchmark's result.
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// benchmark label
    pub name: String,
    /// timed samples taken
    pub samples: usize,
    /// total timed iterations (samples × iterations per sample)
    pub iters: usize,
    /// per-iteration time, seconds
    pub median: f64,
    /// median absolute deviation
    pub mad: f64,
    /// fastest sample (seconds per iteration)
    pub min: f64,
    /// slowest sample (seconds per iteration)
    pub max: f64,
}

impl BenchResult {
    /// Median per-iteration time in nanoseconds.
    pub fn median_ns(&self) -> f64 {
        self.median * 1e9
    }

    /// Median absolute deviation in nanoseconds.
    pub fn mad_ns(&self) -> f64 {
        self.mad * 1e9
    }

    /// One-line human-readable summary.
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12}/iter  (±{:>10}, n={}, min {}, max {})",
            self.name,
            crate::util::timer::fmt_duration(self.median),
            crate::util::timer::fmt_duration(self.mad),
            self.samples,
            crate::util::timer::fmt_duration(self.min),
            crate::util::timer::fmt_duration(self.max),
        )
    }
}

/// Harness configuration.
#[derive(Clone, Copy, Debug)]
pub struct Bencher {
    /// untimed warmup iterations before sampling
    pub warmup_iters: usize,
    /// timed samples to take
    pub samples: usize,
    /// iterations per timed sample (amortizes clock overhead)
    pub iters_per_sample: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Self { warmup_iters: 3, samples: 15, iters_per_sample: 1 }
    }
}

impl Bencher {
    /// Low-sample profile for slow bodies (figure drivers, e2e runs).
    pub fn quick() -> Self {
        Self { warmup_iters: 1, samples: 5, iters_per_sample: 1 }
    }

    /// For sub-millisecond bodies: batch many iters per sample.
    pub fn micro() -> Self {
        Self { warmup_iters: 10, samples: 25, iters_per_sample: 100 }
    }

    /// Run `f` and report per-iteration stats.  `f` takes the
    /// iteration index (so stateful bodies can reset / vary).
    pub fn run<F: FnMut(usize)>(&self, name: &str, mut f: F) -> BenchResult {
        for i in 0..self.warmup_iters * self.iters_per_sample {
            f(i);
        }
        let mut times = Vec::with_capacity(self.samples);
        let mut idx = 0usize;
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..self.iters_per_sample {
                f(idx);
                idx += 1;
            }
            times.push(
                t0.elapsed().as_secs_f64() / self.iters_per_sample as f64,
            );
        }
        let mut result = summarize(name, &mut times);
        result.iters = self.samples * self.iters_per_sample;
        println!("{}", result.report());
        result
    }
}

fn summarize(name: &str, times: &mut [f64]) -> BenchResult {
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = percentile_sorted(times, 0.5);
    let mut devs: Vec<f64> = times.iter().map(|t| (t - median).abs()).collect();
    devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mad = percentile_sorted(&devs, 0.5);
    BenchResult {
        name: name.to_string(),
        samples: times.len(),
        // callers with batched samples (Bencher::run) overwrite this
        iters: times.len(),
        median,
        mad,
        min: times[0],
        max: times[times.len() - 1],
    }
}

fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let idx = (p * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Write results as a machine-readable JSON array — one object per
/// bench with `name`, `median_ns`, `mad_ns`, `iters` (total timed
/// iterations), `samples` (timed sample count), `min_ns`, and
/// `max_ns`.  Parseable by `util::json` (round-trip tested), so the
/// perf trajectory can be diffed PR-over-PR.
pub fn write_json(path: &Path, results: &[BenchResult]) -> std::io::Result<()> {
    fn esc(s: &str) -> String {
        s.replace('\\', "\\\\").replace('"', "\\\"")
    }
    let mut out = String::from("[\n");
    for (i, r) in results.iter().enumerate() {
        out.push_str(&format!(
            "  {{\"name\": \"{}\", \"median_ns\": {:.1}, \"mad_ns\": {:.1}, \
             \"iters\": {}, \"samples\": {}, \"min_ns\": {:.1}, \
             \"max_ns\": {:.1}}}{}\n",
            esc(&r.name),
            r.median_ns(),
            r.mad_ns(),
            r.iters,
            r.samples,
            r.min * 1e9,
            r.max * 1e9,
            if i + 1 < results.len() { "," } else { "" },
        ));
    }
    out.push_str("]\n");
    std::fs::write(path, out)
}

/// Print the standard bench header (called by each bench target).
pub fn header(target: &str) {
    println!("== bench: {target} ==");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_and_mad_of_known_samples() {
        let mut t = vec![3.0, 1.0, 2.0, 100.0, 2.5];
        let r = summarize("x", &mut t);
        assert_eq!(r.median, 2.5);
        assert_eq!(r.min, 1.0);
        assert_eq!(r.max, 100.0);
        // devs from 2.5: [1.5, 0.5, 0, 97.5, 0] sorted → median 0.5
        assert_eq!(r.mad, 0.5);
    }

    #[test]
    fn bencher_runs_expected_iterations() {
        let b = Bencher { warmup_iters: 2, samples: 3, iters_per_sample: 4 };
        let mut count = 0usize;
        b.run("count", |_| count += 1);
        assert_eq!(count, 2 * 4 + 3 * 4);
    }

    #[test]
    fn percentile_degenerate() {
        assert!(percentile_sorted(&[], 0.5).is_nan());
        assert_eq!(percentile_sorted(&[7.0], 0.5), 7.0);
    }

    #[test]
    fn json_report_round_trips_through_the_in_tree_parser() {
        let results = vec![
            BenchResult {
                name: "dot d=784".into(),
                samples: 25,
                iters: 2500,
                median: 1.25e-6,
                mad: 5.0e-9,
                min: 1.2e-6,
                max: 2.0e-6,
            },
            BenchResult {
                name: "server \"fold\" M=9".into(), // exercises escaping
                samples: 15,
                iters: 15,
                median: 3.0e-3,
                mad: 1.0e-4,
                min: 2.9e-3,
                max: 3.3e-3,
            },
        ];
        let path = std::env::temp_dir().join(format!(
            "BENCH_roundtrip_{}.json",
            std::process::id()
        ));
        write_json(&path, &results).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        let j = crate::util::json::Json::parse(&text).unwrap();
        let arr = j.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].str_field("name").unwrap(), "dot d=784");
        assert_eq!(arr[0].usize_field("iters").unwrap(), 2500);
        assert_eq!(arr[0].usize_field("samples").unwrap(), 25);
        assert!(
            (arr[0].get("median_ns").unwrap().as_f64().unwrap() - 1250.0)
                .abs()
                < 0.1
        );
        assert_eq!(arr[1].str_field("name").unwrap(), "server \"fold\" M=9");
        assert!(write_json(&path, &[]).is_ok());
        let empty = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(
            crate::util::json::Json::parse(&empty).unwrap(),
            crate::util::json::Json::Arr(vec![])
        );
    }
}
