//! Uplink compression — the composition the paper's conclusion calls
//! out: "CHB … can potentially be applied along with other
//! complementary techniques such as quantization, compression, and
//! gradient sparsification, to make CHB more efficient in terms of
//! bandwidth per communication as well as the number of
//! communications."
//!
//! A [`Compressor`] encodes the uplink delta δ∇ into a [`Payload`]
//! (dense values, a sparse index/value pair, or a bit-packed
//! quantized buffer — see [`packed`]) plus a simulated wire size.
//! The engine keeps eq. (5) consistent by having the worker
//! advance its θ̂ bookkeeping with the *decoded* payload — the server
//! and worker always agree on Σ transmitted deltas, so the aggregate
//! still telescopes exactly (the compression error shows up as
//! gradient staleness, not divergence; property-tested).
//!
//! The hot path is allocation-free: [`Compressor::compress_into`]
//! writes into a caller-owned [`Payload`] slot (the worker's reusable
//! transmit arena) using a caller-owned [`CodecScratch`] workspace, so
//! a steady-state transmission touches no allocator.  Sparse payloads
//! fold in O(nnz) via [`crate::linalg::axpy_sparse`].

use crate::linalg;
use crate::net::{dense_delta_bits, sparse_delta_bits, sparse_packed_delta_bits};

pub mod packed;

pub use packed::{
    ErrorFeedback, PackScheme, PackedBuf, PackedFp16, PackedFp32, PackedInt,
};

/// An uplink delta as the server folds it: either every coordinate
/// (dense) or only the stored ones (sparse index/value pairs).
///
/// The load-bearing invariant (ARCHITECTURE.md): folding a payload
/// into a vector adds exactly the decoded delta — `Dense` via
/// [`linalg::axpy`], `Sparse` via [`linalg::axpy_sparse`], `Packed`
/// via [`PackedBuf::decode_axpy`] — so Σ folded payloads ≡ Σ
/// worker-side decoded deltas, bit for bit on every stored
/// coordinate.
#[derive(Clone, Debug, PartialEq)]
pub enum Payload {
    /// all `d` coordinates, in order (the uncompressed / quantized form)
    Dense(Vec<f64>),
    /// only the stored coordinates: `idx[j]` holds `val[j]`, all other
    /// coordinates are implicitly zero; indices are strictly ascending
    Sparse {
        /// stored coordinate indices (strictly ascending)
        idx: Vec<u32>,
        /// stored coordinate values (parallel to `idx`)
        val: Vec<f64>,
    },
    /// all `d` coordinates bit-packed into `u64` words (fp32 / fp16 /
    /// n-bit integer fields), decoded on the fly during the fold
    Packed(PackedBuf),
}

impl Default for Payload {
    /// An empty dense payload (what skip reports carry).
    fn default() -> Self {
        Payload::Dense(Vec::new())
    }
}

impl Payload {
    /// Number of coordinates materialized in the payload (`d` for
    /// dense, nnz for sparse).
    pub fn nnz(&self) -> usize {
        match self {
            Payload::Dense(v) => v.len(),
            Payload::Sparse { val, .. } => val.len(),
            Payload::Packed(p) => p.len as usize,
        }
    }

    /// Does the payload store nothing at all?
    pub fn is_empty(&self) -> bool {
        self.nnz() == 0
    }

    /// Can this payload fold into a dimension-`dim` vector?
    pub fn fits(&self, dim: usize) -> bool {
        match self {
            Payload::Dense(v) => v.len() == dim,
            Payload::Sparse { idx, .. } => {
                idx.iter().all(|&i| (i as usize) < dim)
            }
            Payload::Packed(p) => p.len as usize == dim,
        }
    }

    /// y ← y + payload (the server/engine fold primitive): O(d) dense,
    /// O(nnz) sparse, O(d) with in-flight decode for packed.
    pub fn fold_into(&self, y: &mut [f64]) {
        self.axpy_into(1.0, y)
    }

    /// y ← y + a·payload — the scaled fold ([`Payload::fold_into`]
    /// with a = 1; error feedback subtracts the decode with a = −1).
    pub fn axpy_into(&self, a: f64, y: &mut [f64]) {
        match self {
            Payload::Dense(v) => linalg::axpy(a, v, y),
            Payload::Sparse { idx, val } => {
                linalg::axpy_sparse(a, idx, val, y)
            }
            Payload::Packed(p) => p.decode_axpy(a, y),
        }
    }

    /// Materialize the decoded dense vector of dimension `dim`
    /// (diagnostics/tests; the hot path never needs this).
    pub fn to_dense(&self, dim: usize) -> Vec<f64> {
        let mut out = vec![0.0; dim];
        self.fold_into(&mut out);
        out
    }

    /// Convert a sparse or packed payload to its dense decode in place
    /// (`dim` coordinates); dense payloads are left untouched.
    pub fn densify(&mut self, dim: usize) {
        if !matches!(self, Payload::Dense(_)) {
            *self = Payload::Dense(self.to_dense(dim));
        }
    }

    /// Overwrite with a dense copy of `src`, reusing the existing
    /// buffer when the payload is already dense (no allocation once
    /// the capacity is warm).
    pub fn set_dense_from(&mut self, src: &[f64]) {
        match self {
            Payload::Dense(v) => {
                v.clear();
                v.extend_from_slice(src);
            }
            _ => *self = Payload::Dense(src.to_vec()),
        }
    }

    /// Ensure the sparse variant and hand out its (cleared) index and
    /// value buffers for in-place encoding.
    fn sparse_bufs(&mut self) -> (&mut Vec<u32>, &mut Vec<f64>) {
        if !matches!(self, Payload::Sparse { .. }) {
            *self = Payload::Sparse { idx: Vec::new(), val: Vec::new() };
        }
        match self {
            Payload::Sparse { idx, val } => {
                idx.clear();
                val.clear();
                (idx, val)
            }
            _ => unreachable!("just ensured the sparse variant"),
        }
    }

    /// Ensure the dense variant and hand out its (cleared) buffer.
    fn dense_buf(&mut self) -> &mut Vec<f64> {
        if !matches!(self, Payload::Dense(_)) {
            *self = Payload::Dense(Vec::new());
        }
        match self {
            Payload::Dense(v) => {
                v.clear();
                v
            }
            _ => unreachable!("just ensured the dense variant"),
        }
    }

    /// Ensure the packed variant and hand out its buffer for in-place
    /// encoding (the encoders reset it themselves, preserving word
    /// capacity).
    fn packed_buf(&mut self) -> &mut PackedBuf {
        if !matches!(self, Payload::Packed(_)) {
            *self = Payload::Packed(PackedBuf::empty());
        }
        match self {
            Payload::Packed(p) => p,
            _ => unreachable!("just ensured the packed variant"),
        }
    }
}

/// Reusable per-worker codec workspace: scratch a codec may need
/// beyond the output payload itself (top-k keeps its magnitude
/// argsort here, the packed quantizer its level buffer), owned by the
/// caller so repeated compressions allocate nothing.
///
/// This is also where per-worker codec *state* lives: the codec
/// object itself is one `Arc<dyn Compressor>` shared across workers,
/// so anything that must differ per worker — the [`ErrorFeedback`]
/// residual above all — belongs here, in the scratch each `Worker`
/// owns.
#[derive(Debug, Default)]
pub struct CodecScratch {
    /// index permutation buffer (top-k magnitude argsort)
    order: Vec<u32>,
    /// error-feedback working buffer: delta + residual
    corrected: Vec<f64>,
    /// error-feedback carry: quantization error awaiting the next round
    residual: Vec<f64>,
    /// quantization level buffer ([`PackedInt`]'s pre-pack stage)
    quant: Vec<f64>,
}

impl CodecScratch {
    /// The current error-feedback residual (empty until an
    /// [`ErrorFeedback`] codec has run) — diagnostics and the
    /// telescope property test.
    pub fn residual(&self) -> &[f64] {
        &self.residual
    }

    /// Restore the error-feedback residual from a checkpoint (an
    /// empty slice restores the pre-first-compress state).
    pub fn set_residual(&mut self, r: &[f64]) {
        self.residual.clear();
        self.residual.extend_from_slice(r);
    }
}

/// A compressed uplink payload (the allocating convenience form; the
/// hot path uses [`Compressor::compress_into`]).
#[derive(Clone, Debug)]
pub struct Compressed {
    /// the values the server will fold (decoder output)
    pub decoded: Payload,
    /// simulated wire size
    pub bits: u64,
}

/// Lossy uplink codec.
///
/// ```
/// use chb_fed::compress::{Compressor, Payload, TopK, UniformQuantizer};
///
/// // top-k keeps the largest-magnitude coordinates, sparsely…
/// let out = TopK { k: 1 }.compress(&[0.1, -5.0, 0.2]);
/// assert_eq!(out.decoded, Payload::Sparse { idx: vec![1], val: vec![-5.0] });
/// assert_eq!(out.decoded.to_dense(3), vec![0.0, -5.0, 0.0]);
/// assert_eq!(out.bits, 64); // 32-bit index + f32 value
///
/// // …while the quantizer keeps every coordinate at low precision
/// let q = UniformQuantizer { bits: 8 }.compress(&[0.1, -5.0, 0.2]);
/// assert_eq!(q.bits, 32 + 8 * 3);
/// assert!((q.decoded.to_dense(3)[1] + 5.0).abs() < 1e-12); // max is exact
/// ```
pub trait Compressor: Send + Sync {
    /// Encode-decode `delta` into the caller's payload slot, returning
    /// the simulated wire size in bits.  Allocation-free once `out`
    /// and `scratch` have warm capacity — the worker calls this every
    /// transmission with its own arena.
    fn compress_into(
        &self,
        delta: &[f64],
        scratch: &mut CodecScratch,
        out: &mut Payload,
    ) -> u64;

    /// Allocating convenience wrapper around
    /// [`Compressor::compress_into`] (tests, diagnostics).
    fn compress(&self, delta: &[f64]) -> Compressed {
        let mut out = Payload::default();
        let bits =
            self.compress_into(delta, &mut CodecScratch::default(), &mut out);
        Compressed { decoded: out, bits }
    }

    /// Short label for logs and ablation tables.
    fn name(&self) -> &'static str;
}

/// Identity codec: full-precision f64 payload.
pub struct NoCompression;

impl Compressor for NoCompression {
    fn compress_into(
        &self,
        delta: &[f64],
        _scratch: &mut CodecScratch,
        out: &mut Payload,
    ) -> u64 {
        out.set_dense_from(delta);
        dense_delta_bits(delta.len())
    }

    fn name(&self) -> &'static str {
        "none"
    }
}

/// Uniform symmetric quantizer: `bits`-bit signed levels scaled by
/// max|δ|, plus one f32 scale on the wire.  Emits a *dense f64*
/// payload — the historical reference codec; [`PackedInt`] is the
/// bit-packed successor with the same level grid.
pub struct UniformQuantizer {
    /// bits per coordinate (2..=32; range-checked by `RunSpec`
    /// validation — `SpecError::QuantBits` — before any round runs,
    /// so the hot path only debug-asserts)
    pub bits: u32,
}

impl Compressor for UniformQuantizer {
    fn compress_into(
        &self,
        delta: &[f64],
        scratch: &mut CodecScratch,
        out: &mut Payload,
    ) -> u64 {
        debug_assert!(
            (2..=32).contains(&self.bits),
            "validated at the spec layer"
        );
        let maxabs = delta.iter().fold(0.0f64, |m, v| m.max(v.abs()));
        if maxabs == 0.0 {
            let buf = out.dense_buf();
            buf.resize(delta.len(), 0.0);
            return 32;
        }
        let levels = ((1u64 << (self.bits - 1)) - 1) as f64;
        let scale = maxabs / levels;
        // quantize through the shared scratch so the dequantized copy
        // is built without touching the allocator in steady state
        let q = &mut scratch.quant;
        q.clear();
        q.resize(delta.len(), 0.0);
        linalg::simd::kernels().quantize_clamped(
            delta,
            scale.recip(),
            levels,
            q,
        );
        let buf = out.dense_buf();
        buf.extend(q.iter().map(|&lv| lv * scale));
        32 + u64::from(self.bits) * delta.len() as u64
    }

    fn name(&self) -> &'static str {
        "uniform-quant"
    }
}

/// Top-k magnitude sparsifier: emits a [`Payload::Sparse`] directly —
/// k values + k indices on the wire, and an O(k) server fold.
pub struct TopK {
    /// number of coordinates kept (clamped to the vector length)
    pub k: usize,
}

impl Compressor for TopK {
    fn compress_into(
        &self,
        delta: &[f64],
        scratch: &mut CodecScratch,
        out: &mut Payload,
    ) -> u64 {
        let d = delta.len();
        assert!(d <= u32::MAX as usize, "sparse indices are u32");
        let k = self.k.min(d);
        let order = &mut scratch.order;
        order.clear();
        order.extend(0..d as u32);
        // total_cmp, not partial_cmp().unwrap(): a NaN coordinate (a
        // diverged worker) must not panic the whole simulation.  Under
        // the total order NaN sorts as the largest magnitude, so it is
        // kept and surfaces in the fold where the caller can see it.
        // The index tiebreaker makes the order unique, so the unstable
        // (allocation-free) sort is fully deterministic and matches
        // what a stable magnitude sort over 0..d would pick.
        order.sort_unstable_by(|&a, &b| {
            delta[b as usize]
                .abs()
                .total_cmp(&delta[a as usize].abs())
                .then(a.cmp(&b))
        });
        let (idx, val) = out.sparse_bufs();
        idx.extend_from_slice(&order[..k]);
        // canonical form: ascending indices (fold order == index order)
        idx.sort_unstable();
        val.extend(idx.iter().map(|&i| delta[i as usize]));
        sparse_delta_bits(k)
    }

    fn name(&self) -> &'static str {
        "top-k"
    }
}

/// Sparse + packed hybrid: top-k magnitude selection with the kept
/// values quantized on a `bits`-wide uniform grid (scale = max|kept|).
/// On the wire each kept coordinate costs a 32-bit index plus `bits`
/// value bits, under one f32 scale header —
/// [`sparse_packed_delta_bits`] — so `TopKInt { k, bits: 8 }` is 40/64
/// the size of plain [`TopK`] at the same support.  The selection
/// (including the NaN-tolerant total order and index tiebreak) is
/// exactly [`TopK`]'s, and the decoded payload is canonical
/// ascending-index [`Payload::Sparse`], so the O(nnz) server fold and
/// the `DenseDecoded` pin apply unchanged.
pub struct TopKInt {
    /// number of coordinates kept (clamped to the vector length)
    pub k: usize,
    /// value bits per kept coordinate (2..=32; spec-validated)
    pub bits: u32,
}

impl Compressor for TopKInt {
    fn compress_into(
        &self,
        delta: &[f64],
        scratch: &mut CodecScratch,
        out: &mut Payload,
    ) -> u64 {
        debug_assert!(
            (2..=32).contains(&self.bits),
            "validated at the spec layer"
        );
        let d = delta.len();
        assert!(d <= u32::MAX as usize, "sparse indices are u32");
        let k = self.k.min(d);
        let order = &mut scratch.order;
        order.clear();
        order.extend(0..d as u32);
        // identical selection to TopK: NaN-tolerant total order with
        // the index tiebreak, then canonical ascending indices
        order.sort_unstable_by(|&a, &b| {
            delta[b as usize]
                .abs()
                .total_cmp(&delta[a as usize].abs())
                .then(a.cmp(&b))
        });
        let (idx, val) = out.sparse_bufs();
        idx.extend_from_slice(&order[..k]);
        idx.sort_unstable();
        val.extend(idx.iter().map(|&i| delta[i as usize]));
        // quantize the kept values in place (k is small — scalar loop);
        // NaN-tolerant max so a diverged coordinate can't poison scale
        let maxabs = val.iter().fold(0.0f64, |m, v| m.max(v.abs()));
        if maxabs == 0.0 {
            for v in val.iter_mut() {
                *v = 0.0; // includes NaN → level 0, like PackedInt
            }
        } else {
            let levels = ((1u64 << (self.bits - 1)) - 1) as f64;
            let scale = maxabs / levels;
            let inv = scale.recip();
            for v in val.iter_mut() {
                let q = (*v * inv).round().clamp(-levels, levels);
                *v = if q.is_nan() { 0.0 } else { q * scale };
            }
        }
        sparse_packed_delta_bits(self.bits, k)
    }

    fn name(&self) -> &'static str {
        "top-k-int"
    }
}

/// Wrapper that runs an inner codec and densifies its payload — same
/// decoded values and wire bits, dense representation.  Exists to pin
/// the sparse-fold invariant: a run with `TopK` must be bit-identical
/// to the same run with `DenseDecoded(TopK)` (tests/
/// sparse_dense_equivalence.rs).
pub struct DenseDecoded<C>(
    /// the inner codec whose decoded payload gets densified
    pub C,
);

impl<C: Compressor> Compressor for DenseDecoded<C> {
    fn compress_into(
        &self,
        delta: &[f64],
        scratch: &mut CodecScratch,
        out: &mut Payload,
    ) -> u64 {
        let bits = self.0.compress_into(delta, scratch, out);
        out.densify(delta.len());
        bits
    }

    fn name(&self) -> &'static str {
        "dense-decoded"
    }
}

/// Relative ℓ2 error of a codec on a vector (diagnostics/tests).
pub fn relative_error(c: &dyn Compressor, v: &[f64]) -> f64 {
    let out = c.compress(v);
    let decoded = out.decoded.to_dense(v.len());
    let mut diff = 0.0;
    for (a, b) in v.iter().zip(&decoded) {
        diff += (a - b) * (a - b);
    }
    (diff / linalg::norm2_sq(v).max(1e-300)).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ramp(n: usize) -> Vec<f64> {
        (0..n).map(|i| (i as f64 - n as f64 / 2.0) * 0.37).collect()
    }

    #[test]
    fn identity_codec_is_lossless() {
        let v = ramp(33);
        let c = NoCompression.compress(&v);
        assert_eq!(c.decoded, Payload::Dense(v.clone()));
        assert_eq!(c.bits, 64 * 33);
    }

    #[test]
    fn quantizer_error_shrinks_with_bits() {
        let v = ramp(101);
        let e4 = relative_error(&UniformQuantizer { bits: 4 }, &v);
        let e8 = relative_error(&UniformQuantizer { bits: 8 }, &v);
        let e16 = relative_error(&UniformQuantizer { bits: 16 }, &v);
        assert!(e4 > e8 && e8 > e16, "{e4} {e8} {e16}");
        assert!(e16 < 1e-3);
        // bit accounting
        assert_eq!(
            UniformQuantizer { bits: 8 }.compress(&v).bits,
            32 + 8 * 101
        );
    }

    #[test]
    fn quantizer_handles_zero_and_preserves_max() {
        let q = UniformQuantizer { bits: 8 };
        let z = q.compress(&[0.0; 5]);
        assert_eq!(z.decoded, Payload::Dense(vec![0.0; 5]));
        assert_eq!(z.bits, 32);
        let v = vec![-3.0, 0.5, 3.0];
        let out = q.compress(&v).decoded.to_dense(3);
        // endpoints land exactly on the extreme levels
        assert!((out[0] + 3.0).abs() < 1e-12);
        assert!((out[2] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn topk_keeps_largest_magnitudes_sparsely() {
        let v = vec![0.1, -5.0, 0.2, 3.0, -0.05];
        let out = TopK { k: 2 }.compress(&v);
        // sparse payload in canonical ascending-index form
        assert_eq!(
            out.decoded,
            Payload::Sparse { idx: vec![1, 3], val: vec![-5.0, 3.0] }
        );
        assert_eq!(out.decoded.to_dense(5), vec![0.0, -5.0, 0.0, 3.0, 0.0]);
        assert_eq!(out.bits, 128);
        // k ≥ d is lossless
        let all = TopK { k: 99 }.compress(&v);
        assert_eq!(all.decoded.to_dense(5), v);
        assert_eq!(all.decoded.nnz(), 5);
    }

    #[test]
    fn topk_magnitude_ties_break_by_lowest_index() {
        // |v| ties at 2.0 on indices 0, 2, 3 — stable-equivalent order
        let v = vec![2.0, 1.0, -2.0, 2.0];
        let out = TopK { k: 2 }.compress(&v);
        assert_eq!(
            out.decoded,
            Payload::Sparse { idx: vec![0, 2], val: vec![2.0, -2.0] }
        );
    }

    #[test]
    fn topk_tolerates_nan_coordinates() {
        // regression: the magnitude sort used partial_cmp().unwrap(),
        // which panics the moment any coordinate is NaN
        let v = vec![1.0, f64::NAN, 3.0, 0.5];
        let out = TopK { k: 2 }.compress(&v).decoded.to_dense(4);
        // NaN sorts largest under total_cmp → kept alongside 3.0
        assert!(out[1].is_nan());
        assert_eq!(out[0], 0.0);
        assert_eq!(out[2], 3.0);
        assert_eq!(out[3], 0.0);
        // all-NaN input must not panic either
        let all_nan = TopK { k: 1 }.compress(&[f64::NAN, f64::NAN]);
        assert!(all_nan.decoded.to_dense(2).iter().any(|x| x.is_nan()));
    }

    #[test]
    fn topk_int_quantizes_the_topk_support() {
        let v = vec![0.1, -5.0, 0.2, 3.0, -0.05];
        let out = TopKInt { k: 2, bits: 8 }.compress(&v);
        // same support as TopK, canonical ascending indices
        let Payload::Sparse { idx, val } = &out.decoded else {
            panic!("top-k-int must emit sparse");
        };
        assert_eq!(idx, &vec![1, 3]);
        // values land within one 8-bit level of the originals, and the
        // max-magnitude value lands on the extreme level
        let scale = 5.0 / 127.0;
        assert!((val[0] + 5.0).abs() < 1e-12);
        assert!((val[1] - 3.0).abs() <= scale * (1.0 + 1e-12));
        // header + (index + value bits) per kept coordinate
        assert_eq!(out.bits, 32 + (32 + 8) * 2);
        assert!(out.bits < TopK { k: 2 }.compress(&v).bits + 32);
    }

    #[test]
    fn topk_int_error_shrinks_with_bits_and_handles_edge_cases() {
        let v = ramp(101);
        let e4 = relative_error(&TopKInt { k: 101, bits: 4 }, &v);
        let e8 = relative_error(&TopKInt { k: 101, bits: 8 }, &v);
        let e16 = relative_error(&TopKInt { k: 101, bits: 16 }, &v);
        assert!(e4 > e8 && e8 > e16, "{e4} {e8} {e16}");
        // all-zero input: zero values, header + index/value charge
        let z = TopKInt { k: 2, bits: 8 }.compress(&[0.0; 5]);
        assert_eq!(z.decoded.to_dense(5), vec![0.0; 5]);
        assert_eq!(z.bits, 32 + 40 * 2);
        // NaN coordinate is kept (sorts largest) and packs as level 0
        let n = TopKInt { k: 2, bits: 8 }.compress(&[1.0, f64::NAN, 3.0]);
        let dec = n.decoded.to_dense(3);
        assert_eq!(dec[1], 0.0);
        // k ≥ d clamps
        let all = TopKInt { k: 99, bits: 16 }.compress(&[1.0, -2.0]);
        assert_eq!(all.decoded.nnz(), 2);
    }

    #[test]
    fn topk_int_dense_decoded_pin() {
        // the satellite invariant: densifying the hybrid payload
        // changes representation, never the decoded values or bits
        let v = ramp(64);
        let sparse = TopKInt { k: 9, bits: 8 }.compress(&v);
        let dense = DenseDecoded(TopKInt { k: 9, bits: 8 }).compress(&v);
        assert_eq!(dense.bits, sparse.bits);
        assert!(matches!(dense.decoded, Payload::Dense(_)));
        let a = sparse.decoded.to_dense(v.len());
        let b = dense.decoded.to_dense(v.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn compress_into_reuses_buffers_without_reallocating() {
        let mut scratch = CodecScratch::default();
        let mut out = Payload::default();
        let v = ramp(64);
        let c = TopK { k: 8 };
        c.compress_into(&v, &mut scratch, &mut out);
        let (cap_i, cap_v, cap_o) = match &out {
            Payload::Sparse { idx, val } => {
                (idx.capacity(), val.capacity(), scratch.order.capacity())
            }
            _ => panic!("top-k must emit sparse"),
        };
        // steady state: same shapes, capacities must not grow
        for _ in 0..5 {
            c.compress_into(&v, &mut scratch, &mut out);
        }
        match &out {
            Payload::Sparse { idx, val } => {
                assert_eq!(idx.capacity(), cap_i);
                assert_eq!(val.capacity(), cap_v);
                assert_eq!(scratch.order.capacity(), cap_o);
                assert_eq!(idx.len(), 8);
                assert_eq!(val.len(), 8);
            }
            _ => panic!("top-k must emit sparse"),
        }
    }

    #[test]
    fn dense_decoded_wrapper_matches_inner_codec_exactly() {
        let v = ramp(40);
        let sparse = TopK { k: 5 }.compress(&v);
        let dense = DenseDecoded(TopK { k: 5 }).compress(&v);
        assert_eq!(dense.bits, sparse.bits);
        assert!(matches!(dense.decoded, Payload::Dense(_)));
        let a = sparse.decoded.to_dense(v.len());
        let b = dense.decoded.to_dense(v.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn payload_fold_and_fits() {
        let p = Payload::Sparse { idx: vec![0, 3], val: vec![1.5, -2.0] };
        assert_eq!(p.nnz(), 2);
        assert!(!p.is_empty());
        assert!(p.fits(4));
        assert!(!p.fits(3));
        let mut y = vec![1.0; 4];
        p.fold_into(&mut y);
        assert_eq!(y, vec![2.5, 1.0, 1.0, -1.0]);
        let d = Payload::Dense(vec![0.5; 4]);
        assert!(d.fits(4) && !d.fits(5));
        assert!(Payload::default().is_empty());
    }
}
