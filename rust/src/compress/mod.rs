//! Uplink compression — the composition the paper's conclusion calls
//! out: "CHB … can potentially be applied along with other
//! complementary techniques such as quantization, compression, and
//! gradient sparsification, to make CHB more efficient in terms of
//! bandwidth per communication as well as the number of
//! communications."
//!
//! A [`Compressor`] maps the uplink payload δ∇ to a (decoded-value,
//! bit-count) pair.  The engine keeps eq. (5) consistent by having
//! the worker advance its θ̂ bookkeeping with the *decoded* delta —
//! the server and worker always agree on Σ transmitted deltas, so the
//! aggregate still telescopes exactly (the compression error shows up
//! as gradient staleness, not divergence; property-tested).

use crate::linalg;

/// A compressed uplink payload.
#[derive(Clone, Debug)]
pub struct Compressed {
    /// the values the server will fold (decoder output)
    pub decoded: Vec<f64>,
    /// simulated wire size
    pub bits: u64,
}

/// Lossy uplink codec.
///
/// ```
/// use chb_fed::compress::{Compressor, TopK, UniformQuantizer};
///
/// // top-k keeps the largest-magnitude coordinates…
/// let out = TopK { k: 1 }.compress(&[0.1, -5.0, 0.2]);
/// assert_eq!(out.decoded, vec![0.0, -5.0, 0.0]);
/// assert_eq!(out.bits, 64); // 32-bit index + f32 value
///
/// // …while the quantizer keeps every coordinate at low precision
/// let q = UniformQuantizer { bits: 8 }.compress(&[0.1, -5.0, 0.2]);
/// assert_eq!(q.bits, 32 + 8 * 3);
/// assert!((q.decoded[1] + 5.0).abs() < 1e-12); // max is exact
/// ```
pub trait Compressor: Send + Sync {
    /// Encode-decode `delta`, returning the server-side values and the
    /// simulated wire size.
    fn compress(&self, delta: &[f64]) -> Compressed;

    /// Short label for logs and ablation tables.
    fn name(&self) -> &'static str;
}

/// Identity codec: full-precision f64 payload.
pub struct NoCompression;

impl Compressor for NoCompression {
    fn compress(&self, delta: &[f64]) -> Compressed {
        Compressed { decoded: delta.to_vec(), bits: 64 * delta.len() as u64 }
    }

    fn name(&self) -> &'static str {
        "none"
    }
}

/// Uniform symmetric quantizer: `bits`-bit signed levels scaled by
/// max|δ|, plus one f32 scale on the wire.
pub struct UniformQuantizer {
    /// bits per coordinate (2..=32)
    pub bits: u32,
}

impl Compressor for UniformQuantizer {
    fn compress(&self, delta: &[f64]) -> Compressed {
        assert!((2..=32).contains(&self.bits), "need 2..=32 bits");
        let maxabs = delta.iter().fold(0.0f64, |m, v| m.max(v.abs()));
        if maxabs == 0.0 {
            return Compressed { decoded: vec![0.0; delta.len()], bits: 32 };
        }
        let levels = ((1u64 << (self.bits - 1)) - 1) as f64;
        let scale = maxabs / levels;
        let decoded = delta
            .iter()
            .map(|v| (v / scale).round().clamp(-levels, levels) * scale)
            .collect();
        Compressed {
            decoded,
            bits: 32 + u64::from(self.bits) * delta.len() as u64,
        }
    }

    fn name(&self) -> &'static str {
        "uniform-quant"
    }
}

/// Top-k magnitude sparsifier: k values + k indices on the wire.
pub struct TopK {
    /// number of coordinates kept (clamped to the vector length)
    pub k: usize,
}

impl Compressor for TopK {
    fn compress(&self, delta: &[f64]) -> Compressed {
        let d = delta.len();
        let k = self.k.min(d);
        let mut idx: Vec<usize> = (0..d).collect();
        // total_cmp, not partial_cmp().unwrap(): a NaN coordinate (a
        // diverged worker) must not panic the whole simulation.  Under
        // the total order NaN sorts as the largest magnitude, so it is
        // kept and surfaces in the fold where the caller can see it.
        idx.sort_by(|&a, &b| delta[b].abs().total_cmp(&delta[a].abs()));
        let mut decoded = vec![0.0; d];
        for &i in idx.iter().take(k) {
            decoded[i] = delta[i];
        }
        // 32-bit index + f32 value per kept coordinate
        Compressed { decoded, bits: (64 * k) as u64 }
    }

    fn name(&self) -> &'static str {
        "top-k"
    }
}

/// Relative ℓ2 error of a codec on a vector (diagnostics/tests).
pub fn relative_error(c: &dyn Compressor, v: &[f64]) -> f64 {
    let out = c.compress(v);
    let mut diff = 0.0;
    for (a, b) in v.iter().zip(&out.decoded) {
        diff += (a - b) * (a - b);
    }
    (diff / linalg::norm2_sq(v).max(1e-300)).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ramp(n: usize) -> Vec<f64> {
        (0..n).map(|i| (i as f64 - n as f64 / 2.0) * 0.37).collect()
    }

    #[test]
    fn identity_codec_is_lossless() {
        let v = ramp(33);
        let c = NoCompression.compress(&v);
        assert_eq!(c.decoded, v);
        assert_eq!(c.bits, 64 * 33);
    }

    #[test]
    fn quantizer_error_shrinks_with_bits() {
        let v = ramp(101);
        let e4 = relative_error(&UniformQuantizer { bits: 4 }, &v);
        let e8 = relative_error(&UniformQuantizer { bits: 8 }, &v);
        let e16 = relative_error(&UniformQuantizer { bits: 16 }, &v);
        assert!(e4 > e8 && e8 > e16, "{e4} {e8} {e16}");
        assert!(e16 < 1e-3);
        // bit accounting
        assert_eq!(
            UniformQuantizer { bits: 8 }.compress(&v).bits,
            32 + 8 * 101
        );
    }

    #[test]
    fn quantizer_handles_zero_and_preserves_max() {
        let q = UniformQuantizer { bits: 8 };
        let z = q.compress(&[0.0; 5]);
        assert_eq!(z.decoded, vec![0.0; 5]);
        let v = vec![-3.0, 0.5, 3.0];
        let out = q.compress(&v);
        // endpoints land exactly on the extreme levels
        assert!((out.decoded[0] + 3.0).abs() < 1e-12);
        assert!((out.decoded[2] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn topk_keeps_largest_magnitudes() {
        let v = vec![0.1, -5.0, 0.2, 3.0, -0.05];
        let out = TopK { k: 2 }.compress(&v);
        assert_eq!(out.decoded, vec![0.0, -5.0, 0.0, 3.0, 0.0]);
        assert_eq!(out.bits, 128);
        // k ≥ d is lossless
        let all = TopK { k: 99 }.compress(&v);
        assert_eq!(all.decoded, v);
    }

    #[test]
    fn topk_tolerates_nan_coordinates() {
        // regression: the magnitude sort used partial_cmp().unwrap(),
        // which panics the moment any coordinate is NaN
        let v = vec![1.0, f64::NAN, 3.0, 0.5];
        let out = TopK { k: 2 }.compress(&v);
        // NaN sorts largest under total_cmp → kept alongside 3.0
        assert!(out.decoded[1].is_nan());
        assert_eq!(out.decoded[0], 0.0);
        assert_eq!(out.decoded[2], 3.0);
        assert_eq!(out.decoded[3], 0.0);
        assert_eq!(out.bits, 128);
        // all-NaN input must not panic either
        let all_nan = TopK { k: 1 }.compress(&[f64::NAN, f64::NAN]);
        assert!(all_nan.decoded.iter().any(|x| x.is_nan()));
    }
}
