//! Bit-packed quantized uplink codecs: fp32, fp16, and n-bit uniform
//! integer fields packed into `u64` words, with an optional
//! per-worker error-feedback wrapper.
//!
//! Wire format ([`PackedBuf`]): coordinate `j` occupies the
//! `width`-bit field starting at bit `j·width`, little-endian within
//! and across words.  The charged wire size is exactly the packed
//! field bits plus the codec's header ([`crate::net::packed_delta_bits`])
//! — not 64 bits per coordinate — so the bits-to-accuracy ledger
//! reflects what packing actually buys.  Decoding happens on the fly
//! inside [`super::Payload::fold_into`] in O(nnz) = O(d): no dense
//! f64 materialization on either side of the wire.
//!
//! Like every codec here, the *decoded* payload is what both the
//! server fold and the worker's θ̂ bookkeeping consume, so eq. (5)'s
//! telescoping aggregate stays exact and quantization error surfaces
//! as gradient staleness — or, with [`ErrorFeedback`], as a residual
//! carried into the next round instead of lost.
//!
//! Integer schemes keep the dequantization scale as f64 in the
//! simulation while charging a 32-bit (f32) header on the wire — the
//! same convention [`super::UniformQuantizer`] established.

use crate::linalg::{self, simd};
use crate::net::packed_delta_bits;

use super::{CodecScratch, Compressor, Payload};

/// Per-coordinate encoding of a [`PackedBuf`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PackScheme {
    /// IEEE 754 binary32 bit patterns — exact for f32-representable
    /// values, 32 bits/coordinate, no header
    Fp32,
    /// IEEE 754 binary16 (half precision), 16 bits/coordinate
    Fp16,
    /// two's-complement uniform levels q ∈ [−(2^(bits−1)−1),
    /// 2^(bits−1)−1], decoded as q·scale; 32-bit scale header
    Int {
        /// field width in bits (2..=32)
        bits: u32,
    },
}

impl PackScheme {
    /// Wire bits per coordinate.
    pub fn width(self) -> u32 {
        match self {
            PackScheme::Fp32 => 32,
            PackScheme::Fp16 => 16,
            PackScheme::Int { bits } => bits,
        }
    }

    /// Header bits (the f32 scale integer payloads carry).
    pub fn header_bits(self) -> u64 {
        match self {
            PackScheme::Int { .. } => 32,
            _ => 0,
        }
    }
}

/// A bit-packed uplink delta: `len` fields of `scheme.width()` bits
/// each, packed into `words`.
#[derive(Clone, Debug, PartialEq)]
pub struct PackedBuf {
    /// per-coordinate encoding
    pub scheme: PackScheme,
    /// number of coordinates (the full dimension d)
    pub len: u32,
    /// dequantization scale (integer schemes; 1.0 for fp schemes)
    pub scale: f64,
    /// ceil(len·width/64) packed words
    pub words: Vec<u64>,
}

fn words_for(len: usize, width: u32) -> usize {
    ((len as u64 * u64::from(width) + 63) / 64) as usize
}

/// Read a `width`-bit field at absolute bit offset `bit`.
#[inline]
fn read_bits(words: &[u64], bit: usize, width: u32) -> u64 {
    let w = bit / 64;
    let off = (bit % 64) as u32;
    let mask = if width == 64 { u64::MAX } else { (1u64 << width) - 1 };
    let lo = words[w] >> off;
    let got = 64 - off;
    let v = if got >= width { lo } else { lo | (words[w + 1] << got) };
    v & mask
}

/// Write a `width`-bit field (value pre-masked to `width`) at absolute
/// bit offset `bit`; `words` must be zeroed beforehand.
#[inline]
fn write_bits(words: &mut [u64], bit: usize, width: u32, v: u64) {
    let w = bit / 64;
    let off = (bit % 64) as u32;
    words[w] |= v << off;
    let got = 64 - off;
    if got < width {
        words[w + 1] |= v >> got;
    }
}

#[cfg(target_endian = "little")]
fn words_u32(words: &[u64], len: usize) -> &[u32] {
    debug_assert!(len <= words.len() * 2);
    // SAFETY: u64 alignment covers u32; `len` u32s fit inside the
    // words allocation (checked above); on little-endian targets the
    // u32 view is exactly the low/high word halves in field order
    unsafe { core::slice::from_raw_parts(words.as_ptr() as *const u32, len) }
}

#[cfg(target_endian = "little")]
fn words_u32_mut(words: &mut [u64], len: usize) -> &mut [u32] {
    debug_assert!(len <= words.len() * 2);
    // SAFETY: as above, and the borrow is exclusive
    unsafe {
        core::slice::from_raw_parts_mut(words.as_mut_ptr() as *mut u32, len)
    }
}

impl PackedBuf {
    /// An empty buffer (what [`super::Payload::default`]-style slots
    /// start from before the first encode).
    pub fn empty() -> PackedBuf {
        PackedBuf {
            scheme: PackScheme::Fp32,
            len: 0,
            scale: 1.0,
            words: Vec::new(),
        }
    }

    fn reset(&mut self, scheme: PackScheme, len: usize) {
        debug_assert!(len <= u32::MAX as usize, "packed coordinates are u32");
        self.scheme = scheme;
        self.len = len as u32;
        self.scale = 1.0;
        let nw = words_for(len, scheme.width());
        self.words.clear();
        self.words.resize(nw, 0);
    }

    /// Encode `src` as f32 bit patterns (SIMD-dispatched narrowing).
    pub fn encode_fp32(&mut self, src: &[f64]) {
        self.reset(PackScheme::Fp32, src.len());
        #[cfg(target_endian = "little")]
        {
            let dst = words_u32_mut(&mut self.words, src.len());
            simd::kernels().cvt_f64_to_f32_bits(src, dst);
        }
        #[cfg(not(target_endian = "little"))]
        {
            for (j, &v) in src.iter().enumerate() {
                let b = u64::from((v as f32).to_bits());
                self.words[j / 2] |= b << ((j % 2) * 32);
            }
        }
    }

    /// Encode `src` as IEEE half-precision fields.
    pub fn encode_fp16(&mut self, src: &[f64]) {
        self.reset(PackScheme::Fp16, src.len());
        for (j, &v) in src.iter().enumerate() {
            let h = u64::from(f16_bits_from_f64(v));
            self.words[j / 4] |= h << ((j % 4) * 16);
        }
    }

    /// Encode `src` as `bits`-wide uniform levels scaled by max|src|;
    /// `qbuf` is the caller's scratch for the quantized levels (the
    /// SIMD-dispatched front half of the pack).
    pub fn encode_int(&mut self, src: &[f64], bits: u32, qbuf: &mut Vec<f64>) {
        debug_assert!((2..=32).contains(&bits), "validated at the spec layer");
        self.reset(PackScheme::Int { bits }, src.len());
        self.scale = 0.0;
        // NaN-tolerant max: f64::max ignores NaN, so a diverged
        // coordinate can't poison the scale
        let maxabs = src.iter().fold(0.0f64, |m, v| m.max(v.abs()));
        if maxabs == 0.0 {
            return; // all-zero: level 0 everywhere, scale 0
        }
        let levels = ((1u64 << (bits - 1)) - 1) as f64;
        let scale = maxabs / levels;
        self.scale = scale;
        qbuf.clear();
        qbuf.resize(src.len(), 0.0);
        simd::kernels().quantize_clamped(src, scale.recip(), levels, qbuf);
        let mask =
            if bits == 64 { u64::MAX } else { (1u64 << bits) - 1 };
        for (j, &q) in qbuf.iter().enumerate() {
            // NaN casts to 0 — the level a diverged coordinate packs as
            let t = (q as i64) as u64 & mask;
            write_bits(&mut self.words, j * bits as usize, bits, t);
        }
    }

    /// y ← y + a·decode(self), decoding each field on the fly — the
    /// O(nnz) fold primitive [`super::Payload::fold_into`] dispatches
    /// to.  Both wire ends call exactly this, so server and worker
    /// bookkeeping agree bit for bit.
    pub fn decode_axpy(&self, a: f64, y: &mut [f64]) {
        let len = self.len as usize;
        debug_assert!(y.len() >= len);
        match self.scheme {
            PackScheme::Fp32 => {
                #[cfg(target_endian = "little")]
                {
                    let bits = words_u32(&self.words, len);
                    simd::kernels().cvt_f32_bits_axpy(a, bits, &mut y[..len]);
                }
                #[cfg(not(target_endian = "little"))]
                {
                    for j in 0..len {
                        let b = (self.words[j / 2] >> ((j % 2) * 32)) as u32;
                        y[j] += a * f64::from(f32::from_bits(b));
                    }
                }
            }
            PackScheme::Fp16 => {
                for (j, v) in y.iter_mut().enumerate().take(len) {
                    let h = (self.words[j / 4] >> ((j % 4) * 16)) as u16;
                    *v += a * f64_from_f16_bits(h);
                }
            }
            PackScheme::Int { bits } => {
                let shift = 64 - bits;
                for (j, v) in y.iter_mut().enumerate().take(len) {
                    let raw = read_bits(&self.words, j * bits as usize, bits);
                    let q = ((raw << shift) as i64) >> shift;
                    *v += a * (q as f64 * self.scale);
                }
            }
        }
    }
}

/// Lossy-cast codec: every coordinate as an IEEE binary32 bit pattern
/// (32 bits on the wire — half of f64, exact whenever the delta is
/// f32-representable).
pub struct PackedFp32;

impl Compressor for PackedFp32 {
    fn compress_into(
        &self,
        delta: &[f64],
        _scratch: &mut CodecScratch,
        out: &mut Payload,
    ) -> u64 {
        out.packed_buf().encode_fp32(delta);
        packed_delta_bits(32, 0, delta.len())
    }

    fn name(&self) -> &'static str {
        "fp32"
    }
}

/// Half-precision codec: every coordinate as an IEEE binary16 field
/// (16 bits on the wire, ~3 decimal digits).
pub struct PackedFp16;

impl Compressor for PackedFp16 {
    fn compress_into(
        &self,
        delta: &[f64],
        _scratch: &mut CodecScratch,
        out: &mut Payload,
    ) -> u64 {
        out.packed_buf().encode_fp16(delta);
        packed_delta_bits(16, 0, delta.len())
    }

    fn name(&self) -> &'static str {
        "fp16"
    }
}

/// n-bit uniform quantizer emitting genuinely bit-packed fields
/// (`bits` per coordinate + 32-bit scale header), the packed
/// successor to the dense-f64 [`super::UniformQuantizer`].
/// `PackedInt { bits: 8 }` is the paper-ladder "int8" rung.
pub struct PackedInt {
    /// field width in bits (2..=32; range-checked by `RunSpec`
    /// validation before any round runs)
    pub bits: u32,
}

impl Compressor for PackedInt {
    fn compress_into(
        &self,
        delta: &[f64],
        scratch: &mut CodecScratch,
        out: &mut Payload,
    ) -> u64 {
        out.packed_buf().encode_int(delta, self.bits, &mut scratch.quant);
        packed_delta_bits(self.bits, 32, delta.len())
    }

    fn name(&self) -> &'static str {
        "int"
    }
}

/// Error-feedback wrapper: compresses `delta + residual` through the
/// inner codec and carries the quantization remainder into the next
/// round, so codec error accumulates in a local buffer instead of
/// being forgotten.
///
/// The residual lives in the caller's [`CodecScratch`] — per-worker
/// state, matching the per-worker `Arc`-shared-codec split the engine
/// uses.  Telescope invariant (property-tested):
/// Σ decoded + final residual ≡ Σ true deltas, up to f64 rounding of
/// the residual update.
pub struct ErrorFeedback<C>(
    /// the inner (lossy) codec
    pub C,
);

impl<C: Compressor> Compressor for ErrorFeedback<C> {
    fn compress_into(
        &self,
        delta: &[f64],
        scratch: &mut CodecScratch,
        out: &mut Payload,
    ) -> u64 {
        // corrected = delta + residual (residual starts at zero on the
        // first round or a dimension change); take the buffer out so
        // the inner codec can borrow the scratch
        let mut corrected = std::mem::take(&mut scratch.corrected);
        corrected.clear();
        corrected.extend_from_slice(delta);
        if scratch.residual.len() == delta.len() {
            linalg::axpy(1.0, &scratch.residual, &mut corrected);
        } else {
            scratch.residual.clear();
            scratch.residual.resize(delta.len(), 0.0);
        }
        let bits = self.0.compress_into(&corrected, scratch, out);
        // residual ← corrected − decoded
        scratch.residual.copy_from_slice(&corrected);
        out.axpy_into(-1.0, &mut scratch.residual);
        scratch.corrected = corrected;
        bits
    }

    fn name(&self) -> &'static str {
        "error-feedback"
    }
}

/// f64 → IEEE binary16 bits, via f32 with round-to-nearest-even at
/// each narrowing (the standard double-rounding-tolerant path; a
/// lossy codec doesn't chase the composed-rounding ulp).
pub fn f16_bits_from_f64(v: f64) -> u16 {
    f16_bits_from_f32(v as f32)
}

/// IEEE binary16 bits → f64 (exact: every half value is a double).
pub fn f64_from_f16_bits(h: u16) -> f64 {
    f64::from(f32_from_f16_bits(h))
}

fn f16_bits_from_f32(value: f32) -> u16 {
    let x = value.to_bits();
    let sign = x & 0x8000_0000;
    let exp = x & 0x7F80_0000;
    let man = x & 0x007F_FFFF;
    if exp == 0x7F80_0000 {
        // Inf / NaN: keep the top payload bits, force quiet
        let nan_bit = if man == 0 { 0 } else { 0x0200 };
        return ((sign >> 16) | 0x7C00 | nan_bit | (man >> 13)) as u16;
    }
    let half_sign = sign >> 16;
    let half_exp = ((exp >> 23) as i32) - 127 + 15;
    if half_exp >= 0x1F {
        return (half_sign | 0x7C00) as u16; // overflow → ±inf
    }
    if half_exp <= 0 {
        if 14 - half_exp > 24 {
            return half_sign as u16; // underflows past half subnormals
        }
        // subnormal half: shift in the implicit bit, round to nearest
        // even on the truncated tail
        let man = man | 0x0080_0000;
        let mut half_man = man >> (14 - half_exp);
        let round_bit = 1 << (13 - half_exp);
        if (man & round_bit) != 0 && (man & (3 * round_bit - 1)) != 0 {
            half_man += 1;
        }
        return (half_sign | half_man) as u16;
    }
    let half_exp = (half_exp as u32) << 10;
    let half_man = man >> 13;
    let round_bit = 0x0000_1000;
    if (man & round_bit) != 0 && (man & (3 * round_bit - 1)) != 0 {
        // round up; a mantissa carry correctly bumps the exponent
        // (and can legitimately round up to infinity)
        ((half_sign | half_exp | half_man) + 1) as u16
    } else {
        (half_sign | half_exp | half_man) as u16
    }
}

fn f32_from_f16_bits(i: u16) -> f32 {
    if i & 0x7FFF == 0 {
        return f32::from_bits(u32::from(i) << 16); // ±0
    }
    let half_sign = u32::from(i & 0x8000);
    let half_exp = u32::from(i & 0x7C00);
    let half_man = u32::from(i & 0x03FF);
    if half_exp == 0x7C00 {
        if half_man == 0 {
            return f32::from_bits((half_sign << 16) | 0x7F80_0000); // ±inf
        }
        // NaN: set the quiet bit, keep the payload
        return f32::from_bits(
            (half_sign << 16) | 0x7FC0_0000 | (half_man << 13),
        );
    }
    let sign = half_sign << 16;
    if half_exp == 0 {
        // subnormal half → normalized f32
        let e = (half_man as u16).leading_zeros() - 6;
        let exp = (127 - 15 - e) << 23;
        let man = (half_man << (14 + e)) & 0x007F_FFFF;
        return f32::from_bits(sign | exp | man);
    }
    let unbiased_exp = ((half_exp >> 10) as i32) - 15;
    let exp = ((unbiased_exp + 127) as u32) << 23;
    f32::from_bits(sign | exp | (half_man << 13))
}

#[cfg(test)]
mod tests {
    use super::super::relative_error;
    use super::*;

    fn gauss(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = crate::rng::Xoshiro256::new(seed);
        (0..n).map(|_| rng.next_gaussian()).collect()
    }

    #[test]
    fn fp32_roundtrip_is_exact_for_f32_values() {
        let v: Vec<f64> =
            gauss(97, 0xF32).iter().map(|&x| f64::from(x as f32)).collect();
        let out = PackedFp32.compress(&v);
        assert_eq!(out.bits, 32 * 97);
        let dec = out.decoded.to_dense(97);
        for (a, b) in v.iter().zip(&dec) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn fp16_roundtrip_is_exact_for_half_values() {
        // every decodable half bit pattern (skipping NaNs) must
        // re-encode to itself
        for h in (0u16..=0xFFFF).step_by(7) {
            if h & 0x7C00 == 0x7C00 && h & 0x03FF != 0 {
                continue; // NaN patterns don't round-trip bitwise
            }
            let v = f64_from_f16_bits(h);
            let back = f16_bits_from_f64(v);
            // ±0 collapse is the only tolerated alias
            assert_eq!(back, h, "h={h:#06x} v={v}");
        }
        let v: Vec<f64> = vec![1.0, -2.5, 0.09375, 65504.0, -0.25];
        let out = PackedFp16.compress(&v);
        assert_eq!(out.bits, 16 * 5);
        assert_eq!(out.decoded.to_dense(5), v);
    }

    #[test]
    fn fp16_saturates_and_rounds() {
        assert_eq!(f64_from_f16_bits(f16_bits_from_f64(1e6)), f64::INFINITY);
        assert_eq!(
            f64_from_f16_bits(f16_bits_from_f64(-1e6)),
            f64::NEG_INFINITY
        );
        // 2^-25 is the 0 / 2^-24 tie → even (0)
        assert_eq!(f64_from_f16_bits(f16_bits_from_f64(2.0f64.powi(-25))), 0.0);
        assert_eq!(
            f64_from_f16_bits(f16_bits_from_f64(2.0f64.powi(-24))),
            2.0f64.powi(-24)
        );
        assert!(f64_from_f16_bits(f16_bits_from_f64(f64::NAN)).is_nan());
    }

    #[test]
    fn int_pack_respects_quantization_bound() {
        for bits in [2u32, 4, 8, 13, 16, 32] {
            let v = gauss(131, 0x1A7 + u64::from(bits));
            let c = PackedInt { bits };
            let out = c.compress(&v);
            assert_eq!(out.bits, 32 + u64::from(bits) * 131);
            let dec = out.decoded.to_dense(131);
            let maxabs = v.iter().fold(0.0f64, |m, x| m.max(x.abs()));
            let levels = ((1u64 << (bits - 1)) - 1) as f64;
            let bound = maxabs / levels; // one full level, reciprocal-safe
            for (a, b) in v.iter().zip(&dec) {
                assert!(
                    (a - b).abs() <= bound * (1.0 + 1e-12),
                    "bits={bits} a={a} b={b}"
                );
            }
        }
    }

    #[test]
    fn int_pack_handles_zero_and_nan() {
        let z = PackedInt { bits: 8 }.compress(&[0.0; 9]);
        assert_eq!(z.decoded.to_dense(9), vec![0.0; 9]);
        // NaN coordinate packs as level 0 without panicking, and the
        // finite coordinates survive
        let out = PackedInt { bits: 8 }.compress(&[1.0, f64::NAN, -1.0]);
        let dec = out.decoded.to_dense(3);
        assert!((dec[0] - 1.0).abs() < 1e-2);
        assert_eq!(dec[1], 0.0);
        assert!((dec[2] + 1.0).abs() < 1e-2);
    }

    #[test]
    fn packed_error_shrinks_with_width() {
        let v = gauss(257, 0xE44);
        let e8 = relative_error(&PackedInt { bits: 8 }, &v);
        let e16 = relative_error(&PackedFp16, &v);
        let e32 = relative_error(&PackedFp32, &v);
        assert!(e8 > e16 && e16 > e32, "{e8} {e16} {e32}");
        assert!(e32 < 1e-7);
    }

    #[test]
    fn odd_widths_cross_word_boundaries_correctly() {
        // width 13 guarantees fields straddling u64 boundaries
        let mut words = vec![0u64; words_for(40, 13)];
        for j in 0..40 {
            write_bits(&mut words, j * 13, 13, (j as u64 * 211) & 0x1FFF);
        }
        for j in 0..40 {
            let want = (j as u64 * 211) & 0x1FFF;
            assert_eq!(read_bits(&words, j * 13, 13), want);
        }
    }

    #[test]
    fn compress_into_reuses_packed_buffers() {
        let mut scratch = CodecScratch::default();
        let mut out = Payload::default();
        let v = gauss(64, 0xBEEF);
        let c = PackedInt { bits: 8 };
        c.compress_into(&v, &mut scratch, &mut out);
        let cap = match &out {
            Payload::Packed(p) => p.words.capacity(),
            _ => panic!("packed codec must emit Packed"),
        };
        for _ in 0..5 {
            c.compress_into(&v, &mut scratch, &mut out);
        }
        match &out {
            Payload::Packed(p) => {
                assert_eq!(p.words.capacity(), cap);
                assert_eq!(p.len, 64);
            }
            _ => panic!("packed codec must emit Packed"),
        }
    }

    #[test]
    fn dense_decoded_pins_packed_codecs() {
        // ARCHITECTURE.md invariant 3 extended: densifying a packed
        // payload changes the representation, never the decoded values
        let v = gauss(50, 0xD15C);
        let cases: Vec<Box<dyn Compressor>> = vec![
            Box::new(PackedFp32),
            Box::new(PackedFp16),
            Box::new(PackedInt { bits: 8 }),
        ];
        for c in &cases {
            let packed = c.compress(&v);
            let mut densified = packed.decoded.clone();
            densified.densify(50);
            assert!(matches!(densified, Payload::Dense(_)));
            let a = packed.decoded.to_dense(50);
            let b = densified.to_dense(50);
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.to_bits(), y.to_bits(), "{}", c.name());
            }
        }
    }

    #[test]
    fn error_feedback_telescopes() {
        let d = 33;
        let mut scratch = CodecScratch::default();
        let mut out = Payload::default();
        let c = ErrorFeedback(PackedInt { bits: 4 });
        let mut sum_true = vec![0.0; d];
        let mut sum_dec = vec![0.0; d];
        for round in 0..40 {
            let delta = gauss(d, 0xEF0 + round);
            linalg::axpy(1.0, &delta, &mut sum_true);
            c.compress_into(&delta, &mut scratch, &mut out);
            out.fold_into(&mut sum_dec);
        }
        // Σ decoded + final residual ≡ Σ true deltas (up to f64
        // rounding of the running sums)
        let res = scratch.residual();
        for j in 0..d {
            let lhs = sum_dec[j] + res[j];
            assert!(
                (lhs - sum_true[j]).abs() < 1e-9,
                "j={j}: {lhs} vs {}",
                sum_true[j]
            );
        }
        // and the residual is genuinely bounded (error feedback does
        // not blow up): one quantization level of the last round
        assert!(res.iter().all(|r| r.abs() < 2.0));
    }

    #[test]
    fn error_feedback_improves_int4_on_repeated_delta() {
        // with a constant delta the EF residual makes the *sum* of
        // decodes track k·delta far better than k independent decodes
        let d = 20;
        let delta = gauss(d, 0x5EED);
        let rounds = 50;
        let mut ef_scr = CodecScratch::default();
        let mut ef_out = Payload::default();
        let ef = ErrorFeedback(PackedInt { bits: 4 });
        let raw = PackedInt { bits: 4 };
        let mut raw_scr = CodecScratch::default();
        let mut raw_out = Payload::default();
        let mut ef_sum = vec![0.0; d];
        let mut raw_sum = vec![0.0; d];
        for _ in 0..rounds {
            ef.compress_into(&delta, &mut ef_scr, &mut ef_out);
            ef_out.fold_into(&mut ef_sum);
            raw.compress_into(&delta, &mut raw_scr, &mut raw_out);
            raw_out.fold_into(&mut raw_sum);
        }
        let err = |sum: &[f64]| -> f64 {
            sum.iter()
                .zip(&delta)
                .map(|(s, t)| (s - t * rounds as f64).powi(2))
                .sum::<f64>()
                .sqrt()
        };
        assert!(
            err(&ef_sum) < err(&raw_sum) / 4.0,
            "ef {} raw {}",
            err(&ef_sum),
            err(&raw_sum)
        );
    }
}
