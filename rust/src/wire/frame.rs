//! The versioned, length-framed binary protocol unit.
//!
//! One frame on the wire is
//!
//! ```text
//! offset  size  field
//!      0     4  magic  "CHBW"
//!      4     2  version (LE u16, currently 1)
//!      6     1  kind    (FrameKind discriminant)
//!      7     1  flags   (reserved, 0)
//!      8     8  round   (LE u64 — the server step k this frame belongs to)
//!     16     8  seq     (LE u64 — per-connection, per-direction counter)
//!     24     4  len     (LE u32 — body byte length)
//!     28   len  body    (UTF-8 JSON; floats as 16-hex-digit bit patterns)
//! 28+len     4  crc32   (LE u32, IEEE, over header + body)
//! ```
//!
//! The body reuses the checkpoint module's hex-bit-pattern codecs, so
//! every f64 that crosses the wire is bitwise-faithful — the loopback
//! wire run is bit-identical to the in-process serial engine because
//! nothing is ever rounded through decimal text.
//!
//! Decoding is strict and total: truncation, a flipped bit, a bad
//! CRC, an unknown kind, or a version bump all surface as typed
//! [`WireError`]s *before* any engine state is touched.  A CRC/body
//! failure consumes exactly one frame from the stream (the length
//! field is covered by the header), so a corrupted frame never
//! desynchronizes the connection.

use std::io::{Read, Write};
use std::sync::Arc;

use crate::checkpoint::{self, CheckpointError};
use crate::coordinator::{WorkerRound, WorkerSnapshot};
use crate::util::json::Json;

/// Frame magic: the first four bytes of every frame.
pub const MAGIC: [u8; 4] = *b"CHBW";

/// Wire protocol version; a mismatch is rejected before the body is
/// even length-checked.
pub const WIRE_VERSION: u16 = 1;

/// Fixed header size in bytes (everything before the body).
pub const HEADER_LEN: usize = 28;

/// CRC trailer size in bytes.
pub const CRC_LEN: usize = 4;

/// Upper bound on a frame body — a length field beyond this is
/// rejected as [`WireError::Oversize`] instead of allocating.
pub const MAX_BODY_LEN: u32 = 256 * 1024 * 1024;

/// What a frame is — the message vocabulary of the round protocol.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrameKind {
    /// client → server: worker announces (id, dim, spec hash)
    Hello = 1,
    /// server → client: cohort shape (M, dim, spec hash) accepted
    Welcome = 2,
    /// server → client: one round's broadcast (θᵏ, step², flags, ack)
    Round = 3,
    /// client → server: the worker's [`WorkerRound`] report
    Report = 4,
    /// either direction: liveness probe (echoed by the peer)
    Heartbeat = 5,
    /// server → client: request a [`WorkerSnapshot`] (checkpointing)
    SnapshotReq = 6,
    /// client → server: the snapshot reply
    Snapshot = 7,
    /// server → client: install this snapshot (resume / reconnect)
    Restore = 8,
    /// client → server: snapshot installed
    RestoreAck = 9,
    /// server → client: run over; final ack round enclosed
    Bye = 10,
}

impl FrameKind {
    /// Decode a kind byte; unknown values are a typed error.
    pub fn from_u8(b: u8) -> Result<FrameKind, WireError> {
        Ok(match b {
            1 => FrameKind::Hello,
            2 => FrameKind::Welcome,
            3 => FrameKind::Round,
            4 => FrameKind::Report,
            5 => FrameKind::Heartbeat,
            6 => FrameKind::SnapshotReq,
            7 => FrameKind::Snapshot,
            8 => FrameKind::Restore,
            9 => FrameKind::RestoreAck,
            10 => FrameKind::Bye,
            other => return Err(WireError::BadKind(other)),
        })
    }
}

/// Everything that can go wrong on the wire, typed.  Every decode
/// failure is raised before any engine state mutates, and (except for
/// stream-level I/O faults) identifies exactly one bad frame.
#[derive(Debug)]
pub enum WireError {
    /// socket-level failure
    Io(std::io::Error),
    /// first four bytes were not `"CHBW"` — the stream is garbage
    BadMagic([u8; 4]),
    /// protocol version mismatch
    Version {
        /// version the peer sent
        got: u16,
    },
    /// unknown [`FrameKind`] discriminant
    BadKind(u8),
    /// a strict whole-buffer decode got fewer bytes than the frame needs
    Truncated {
        /// bytes the frame claims to span
        need: usize,
        /// bytes actually available
        got: usize,
    },
    /// body length field exceeds [`MAX_BODY_LEN`]
    Oversize {
        /// the claimed body length
        len: u32,
    },
    /// checksum mismatch — the frame was damaged in flight
    Crc {
        /// CRC the sender wrote
        want: u32,
        /// CRC computed over the received bytes
        got: u32,
    },
    /// the body failed strict JSON decoding
    Body(String),
    /// the peer violated the round protocol
    Protocol(String),
    /// the peer closed the connection
    Disconnected,
    /// a bounded wait expired
    Timeout(String),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Io(e) => write!(f, "wire i/o: {e}"),
            WireError::BadMagic(m) => {
                write!(f, "bad frame magic {m:02x?} (expected \"CHBW\")")
            }
            WireError::Version { got } => write!(
                f,
                "wire protocol version {got} (this build speaks \
                 {WIRE_VERSION})"
            ),
            WireError::BadKind(b) => write!(f, "unknown frame kind {b}"),
            WireError::Truncated { need, got } => {
                write!(f, "truncated frame: need {need} bytes, got {got}")
            }
            WireError::Oversize { len } => write!(
                f,
                "frame body of {len} bytes exceeds the {MAX_BODY_LEN} cap"
            ),
            WireError::Crc { want, got } => {
                write!(f, "crc mismatch: frame says {want:08x}, got {got:08x}")
            }
            WireError::Body(d) => write!(f, "frame body: {d}"),
            WireError::Protocol(d) => write!(f, "protocol violation: {d}"),
            WireError::Disconnected => write!(f, "peer disconnected"),
            WireError::Timeout(d) => write!(f, "timed out: {d}"),
        }
    }
}

impl std::error::Error for WireError {}

impl From<std::io::Error> for WireError {
    fn from(e: std::io::Error) -> Self {
        WireError::Io(e)
    }
}

impl From<CheckpointError> for WireError {
    fn from(e: CheckpointError) -> Self {
        WireError::Body(e.to_string())
    }
}

// CRC-32 (IEEE 802.3, reflected), table generated at compile time.
const CRC_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut j = 0;
        while j < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            j += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
};

/// CRC-32 (IEEE) of `bytes` — the checksum every frame trailer carries.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

/// One decoded protocol frame.
#[derive(Clone, Debug, PartialEq)]
pub struct Frame {
    /// what this frame is
    pub kind: FrameKind,
    /// server step k the frame belongs to (0 for handshake frames)
    pub round: u64,
    /// per-connection, per-direction monotonic counter — the receiver
    /// discards any frame whose seq does not advance, which is what
    /// makes chaos-duplicated and reordered frames harmless
    pub seq: u64,
    /// JSON body (empty object for bodyless kinds)
    pub body: Json,
}

impl Frame {
    /// Build a frame.
    pub fn new(kind: FrameKind, round: u64, seq: u64, body: Json) -> Frame {
        Frame { kind, round, seq, body }
    }

    /// Encode to the byte layout documented at module level.
    pub fn encode(&self) -> Vec<u8> {
        let body = self.body.dump();
        let body = body.as_bytes();
        let mut out = Vec::with_capacity(HEADER_LEN + body.len() + CRC_LEN);
        out.extend_from_slice(&MAGIC);
        out.extend_from_slice(&WIRE_VERSION.to_le_bytes());
        out.push(self.kind as u8);
        out.push(0); // flags, reserved
        out.extend_from_slice(&self.round.to_le_bytes());
        out.extend_from_slice(&self.seq.to_le_bytes());
        out.extend_from_slice(&(body.len() as u32).to_le_bytes());
        out.extend_from_slice(body);
        let crc = crc32(&out);
        out.extend_from_slice(&crc.to_le_bytes());
        out
    }

    /// Strict whole-buffer decode: `buf` must hold exactly one frame.
    /// Every validation (magic, version, kind, length, CRC, body JSON)
    /// runs before anything is returned, so a caller can never act on
    /// a damaged frame.
    pub fn decode(buf: &[u8]) -> Result<Frame, WireError> {
        if buf.len() < HEADER_LEN + CRC_LEN {
            return Err(WireError::Truncated {
                need: HEADER_LEN + CRC_LEN,
                got: buf.len(),
            });
        }
        if buf[0..4] != MAGIC {
            return Err(WireError::BadMagic([
                buf[0], buf[1], buf[2], buf[3],
            ]));
        }
        let version = u16::from_le_bytes([buf[4], buf[5]]);
        if version != WIRE_VERSION {
            return Err(WireError::Version { got: version });
        }
        let kind = FrameKind::from_u8(buf[6])?;
        let round = u64::from_le_bytes(buf[8..16].try_into().unwrap());
        let seq = u64::from_le_bytes(buf[16..24].try_into().unwrap());
        let len = u32::from_le_bytes(buf[24..28].try_into().unwrap());
        if len > MAX_BODY_LEN {
            return Err(WireError::Oversize { len });
        }
        let total = HEADER_LEN + len as usize + CRC_LEN;
        if buf.len() != total {
            return Err(WireError::Truncated { need: total, got: buf.len() });
        }
        let want = u32::from_le_bytes(buf[total - 4..total].try_into().unwrap());
        let got = crc32(&buf[..total - 4]);
        if want != got {
            return Err(WireError::Crc { want, got });
        }
        let body_bytes = &buf[HEADER_LEN..total - 4];
        let text = std::str::from_utf8(body_bytes)
            .map_err(|e| WireError::Body(format!("not UTF-8: {e}")))?;
        let body = Json::parse(text)
            .map_err(|e| WireError::Body(format!("parse: {e}")))?;
        Ok(Frame { kind, round, seq, body })
    }

    /// Streaming decode from a read buffer: returns `Ok(None)` while
    /// the buffer holds less than one complete frame, and drains
    /// exactly one frame's bytes otherwise — *including* when that
    /// frame fails CRC or body validation, so one damaged frame costs
    /// one frame, never the connection.
    pub fn take(buf: &mut Vec<u8>) -> Result<Option<Frame>, WireError> {
        if buf.len() >= 4 && buf[0..4] != MAGIC {
            return Err(WireError::BadMagic([buf[0], buf[1], buf[2], buf[3]]));
        }
        if buf.len() >= 6 {
            let version = u16::from_le_bytes([buf[4], buf[5]]);
            if version != WIRE_VERSION {
                return Err(WireError::Version { got: version });
            }
        }
        if buf.len() < HEADER_LEN {
            return Ok(None);
        }
        let len = u32::from_le_bytes(buf[24..28].try_into().unwrap());
        if len > MAX_BODY_LEN {
            return Err(WireError::Oversize { len });
        }
        let total = HEADER_LEN + len as usize + CRC_LEN;
        if buf.len() < total {
            return Ok(None);
        }
        let result = Frame::decode(&buf[..total]);
        buf.drain(..total);
        result.map(Some)
    }
}

/// Per-connection receive state: a byte buffer that frames are carved
/// out of.  One `poll` performs at most one socket read, so a caller
/// multiplexing many connections stays responsive.
#[derive(Default)]
pub struct FrameReader {
    buf: Vec<u8>,
}

impl FrameReader {
    /// Fresh reader (empty buffer).
    pub fn new() -> FrameReader {
        FrameReader::default()
    }

    /// Try to produce one frame: first from buffered bytes, then from
    /// one socket read.  `Ok(None)` means "no complete frame yet"
    /// (including read timeouts on a socket with a read deadline);
    /// [`WireError::Disconnected`] means the peer closed cleanly.
    pub fn poll(
        &mut self,
        r: &mut impl Read,
    ) -> Result<Option<Frame>, WireError> {
        if let Some(f) = Frame::take(&mut self.buf)? {
            return Ok(Some(f));
        }
        let mut chunk = [0u8; 16 * 1024];
        match r.read(&mut chunk) {
            Ok(0) => return Err(WireError::Disconnected),
            Ok(n) => self.buf.extend_from_slice(&chunk[..n]),
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                return Ok(None)
            }
            Err(e) => return Err(e.into()),
        }
        Frame::take(&mut self.buf)
    }
}

/// Write one frame and flush it.
pub fn write_frame(w: &mut impl Write, f: &Frame) -> Result<(), WireError> {
    w.write_all(&f.encode())?;
    w.flush()?;
    Ok(())
}

// ---------------------------------------------------------------------------
// body codecs — strict JSON, floats as hex bit patterns
// ---------------------------------------------------------------------------

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn bool_field(
    o: &std::collections::BTreeMap<String, Json>,
    key: &str,
    what: &str,
) -> Result<bool, WireError> {
    match checkpoint::req(o, key, what)? {
        Json::Bool(b) => Ok(*b),
        _ => Err(WireError::Body(format!("{what}.{key} is not a bool"))),
    }
}

/// `Hello` body: the worker's identity card.
pub fn hello_body(worker: usize, dim: usize, spec_hash: Option<u64>) -> Json {
    obj(vec![
        ("worker", Json::Num(worker as f64)),
        ("dim", Json::Num(dim as f64)),
        (
            "spec_hash",
            match spec_hash {
                Some(h) => Json::Str(checkpoint::hex_u64(h)),
                None => Json::Null,
            },
        ),
    ])
}

/// Decoded `Hello` body.
pub struct HelloMsg {
    /// announcing worker id
    pub worker: usize,
    /// the worker's parameter dimension
    pub dim: usize,
    /// FNV-1a hash of the worker's manifest (None when spec-less)
    pub spec_hash: Option<u64>,
}

/// Decode a `Hello` body.
pub fn parse_hello(body: &Json) -> Result<HelloMsg, WireError> {
    let o = checkpoint::as_obj(body, "hello")?;
    checkpoint::check_keys(o, &["worker", "dim", "spec_hash"], &[], "hello")?;
    let spec_hash = match checkpoint::req(o, "spec_hash", "hello")? {
        Json::Null => None,
        v => Some(checkpoint::u64_from_json(v, "hello.spec_hash")?),
    };
    Ok(HelloMsg {
        worker: checkpoint::num_field(o, "worker", "hello")? as usize,
        dim: checkpoint::num_field(o, "dim", "hello")? as usize,
        spec_hash,
    })
}

/// `Welcome` body: the cohort shape the server accepted the worker into.
pub fn welcome_body(m: usize, dim: usize, spec_hash: Option<u64>) -> Json {
    obj(vec![
        ("m", Json::Num(m as f64)),
        ("dim", Json::Num(dim as f64)),
        (
            "spec_hash",
            match spec_hash {
                Some(h) => Json::Str(checkpoint::hex_u64(h)),
                None => Json::Null,
            },
        ),
    ])
}

/// Decoded `Welcome` body.
pub struct WelcomeMsg {
    /// cohort size M
    pub m: usize,
    /// server-side parameter dimension
    pub dim: usize,
    /// server's manifest hash
    pub spec_hash: Option<u64>,
}

/// Decode a `Welcome` body.
pub fn parse_welcome(body: &Json) -> Result<WelcomeMsg, WireError> {
    let o = checkpoint::as_obj(body, "welcome")?;
    checkpoint::check_keys(o, &["m", "dim", "spec_hash"], &[], "welcome")?;
    let spec_hash = match checkpoint::req(o, "spec_hash", "welcome")? {
        Json::Null => None,
        v => Some(checkpoint::u64_from_json(v, "welcome.spec_hash")?),
    };
    Ok(WelcomeMsg {
        m: checkpoint::num_field(o, "m", "welcome")? as usize,
        dim: checkpoint::num_field(o, "dim", "welcome")? as usize,
        spec_hash,
    })
}

/// `Round` body.  `theta_hex` is the pre-encoded iterate (encoded once
/// per round, shared across the cohort's frames); `acked` is the
/// highest round whose report from this worker the server has folded —
/// the client resolves its pending transactional transmit against it.
pub fn round_body(
    theta_hex: &Json,
    step_sq: f64,
    active: bool,
    force: bool,
    acked: u64,
) -> Json {
    obj(vec![
        ("theta", theta_hex.clone()),
        ("step_sq", Json::Str(checkpoint::hex_f64(step_sq))),
        ("active", Json::Bool(active)),
        ("force", Json::Bool(force)),
        ("acked", Json::Str(checkpoint::hex_u64(acked))),
    ])
}

/// Decoded `Round` body.
pub struct RoundMsg {
    /// broadcast iterate θᵏ
    pub theta: Vec<f64>,
    /// ‖θᵏ − θ^{k−1}‖² (the censor threshold's RHS scale)
    pub step_sq: f64,
    /// is this worker scheduled this round?
    pub active: bool,
    /// bypass the censor (rejoin / resync semantics)
    pub force: bool,
    /// highest round of this worker the server has folded
    pub acked: u64,
}

/// Decode a `Round` body.
pub fn parse_round(body: &Json) -> Result<RoundMsg, WireError> {
    let o = checkpoint::as_obj(body, "round")?;
    checkpoint::check_keys(
        o,
        &["theta", "step_sq", "active", "force", "acked"],
        &[],
        "round",
    )?;
    Ok(RoundMsg {
        theta: checkpoint::f64_vec_field(o, "theta", "round")?,
        step_sq: checkpoint::f64_from_json(
            checkpoint::req(o, "step_sq", "round")?,
            "round.step_sq",
        )?,
        active: bool_field(o, "active", "round")?,
        force: bool_field(o, "force", "round")?,
        acked: checkpoint::u64_from_json(
            checkpoint::req(o, "acked", "round")?,
            "round.acked",
        )?,
    })
}

/// `Report` body: the checkpoint module's [`WorkerRound`] codec, so a
/// report crossing the wire is bitwise the report a serial pool hands
/// the engine in-process.
pub fn report_body(r: &WorkerRound) -> Json {
    checkpoint::round_to_json(r)
}

/// Decode a `Report` body into a [`WorkerRound`].
pub fn parse_report(body: &Json) -> Result<WorkerRound, WireError> {
    Ok(checkpoint::round_from_json(body)?)
}

/// `Snapshot` / `Restore` body: a [`WorkerSnapshot`] with the same key
/// set and hex encoding the checkpoint file uses for worker state.
pub fn snapshot_body(s: &WorkerSnapshot) -> Json {
    obj(vec![
        ("id", Json::Num(s.id as f64)),
        ("last_tx", checkpoint::hex_f64_vec(&s.last_tx)),
        ("transmissions", Json::Num(s.transmissions as f64)),
        ("residual", checkpoint::hex_f64_vec(&s.residual)),
    ])
}

/// Decode a `Snapshot` / `Restore` body.
pub fn parse_snapshot(body: &Json) -> Result<WorkerSnapshot, WireError> {
    let o = checkpoint::as_obj(body, "snapshot")?;
    checkpoint::check_keys(
        o,
        &["id", "last_tx", "transmissions", "residual"],
        &[],
        "snapshot",
    )?;
    Ok(WorkerSnapshot {
        id: checkpoint::num_field(o, "id", "snapshot")? as usize,
        last_tx: checkpoint::f64_vec_field(o, "last_tx", "snapshot")?,
        transmissions: checkpoint::num_field(o, "transmissions", "snapshot")?
            as usize,
        residual: checkpoint::f64_vec_field(o, "residual", "snapshot")?,
    })
}

/// `Bye` body: the final ack round, so a client can commit a pending
/// transactional transmit before exiting.
pub fn bye_body(acked: u64) -> Json {
    obj(vec![("acked", Json::Str(checkpoint::hex_u64(acked)))])
}

/// Decode a `Bye` body.
pub fn parse_bye(body: &Json) -> Result<u64, WireError> {
    let o = checkpoint::as_obj(body, "bye")?;
    checkpoint::check_keys(o, &["acked"], &[], "bye")?;
    checkpoint::u64_from_json(checkpoint::req(o, "acked", "bye")?, "bye.acked")
        .map_err(WireError::from)
}

/// Empty body for bodyless frame kinds.
pub fn empty_body() -> Json {
    Json::Obj(std::collections::BTreeMap::new())
}

/// A synthesized skip report — what the server folds for a worker that
/// missed its round deadline (quorum degradation).  Shape-identical to
/// [`crate::coordinator::Worker::observe`]'s report: zero loss
/// contribution is *not* claimed — the loss field is 0.0 and the
/// `batch_frac` 0.0 marks it as a non-computing observer.
pub fn synthesized_skip(worker: usize) -> WorkerRound {
    WorkerRound {
        worker,
        decision: crate::optim::CensorDecision::Skip,
        delta: Arc::new(crate::compress::Payload::default()),
        loss: 0.0,
        delta_sq: 0.0,
        bits: 0,
        batch_frac: 0.0,
    }
}
