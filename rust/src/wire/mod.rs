//! Layer-3b: the censored-heavy-ball round protocol over real sockets.
//!
//! Everything the in-process engines simulate — broadcast, censored
//! uplinks, participation, faults — here crosses a versioned,
//! length-framed binary protocol (TCP or Unix-domain, std-only):
//!
//! * [`frame`] — the frame codec: `"CHBW"` magic, version, kind, seq,
//!   CRC32 trailer, JSON bodies with hex-bit-pattern f64s (the
//!   checkpoint codec), so wire state is bitwise-faithful.
//! * [`transport`] — TCP/UDS listeners and connections behind one
//!   enum, plus the seeded exponential-backoff [`RetryPolicy`].
//! * [`chaos`] — [`ChaosSpec`]: drop/delay/duplicate/corrupt/partition
//!   as a pure function of `(seed, link, round, attempt)`.
//! * [`server`] — [`WirePool`], a [`crate::coordinator::WorkerPool`]
//!   whose workers live across sockets; heartbeats, bounded retries,
//!   quorum folds, reconnect-restore.
//! * [`client`] — [`run_client`], the worker process loop:
//!   transactional rounds, cached retransmits, redial-with-backoff.
//! * [`loadgen`] — a closed-loop throughput/latency harness driving
//!   hundreds of loopback clients against one pool.
//!
//! The load-bearing property (ARCHITECTURE.md invariant 6): with zero
//! chaos and full participation, a loopback wire run is bit-identical
//! to the in-process serial engine — same trace, same per-worker
//! transmission counts — because [`WirePool`] feeds the *same* round
//! engine id-ordered, bit-exact reports.

pub mod chaos;
pub mod client;
pub mod frame;
pub mod loadgen;
pub mod server;
pub mod transport;

pub use chaos::{ChaosAction, ChaosSpec, LinkDir};
pub use client::{run_client, ClientConfig, ClientStats};
pub use frame::{Frame, FrameKind, FrameReader, WireError, WIRE_VERSION};
pub use loadgen::{run_loadgen, LoadgenConfig, LoadgenReport};
pub use server::{WireConfig, WirePool, WireStats};
pub use transport::{Conn, Listener, RetryPolicy, TransportSpec};

use std::sync::Arc;

use crate::checkpoint::CheckpointError;
use crate::coordinator::engine::{run_with_rules_ctx, RunConfig, RunContext};
use crate::coordinator::{Server, Worker};
use crate::metrics::Trace;
use crate::optim::CensorRule;

/// Run the full round engine over a loopback wire deployment: one
/// [`WirePool`] server and one client thread per worker, all inside
/// this process.  This is what `EngineKind::Wire` dispatches to — the
/// same protocol bytes a multi-process deployment exchanges, minus the
/// process boundary.
pub fn run_loopback_ctx(
    wcfg: &WireConfig,
    workers: Vec<Worker>,
    cfg: &RunConfig,
    server: Server,
    censor: Arc<dyn CensorRule>,
    label: &str,
    ctx: &RunContext,
) -> Result<Trace, CheckpointError> {
    let m = workers.len();
    let dim = server.dim();
    let (listener, addr) =
        Listener::bind_loopback().expect("bind loopback listener");
    let handles: Vec<_> = workers
        .into_iter()
        .map(|mut w| {
            let censor = Arc::clone(&censor);
            let ccfg = ClientConfig {
                spec_hash: ctx.spec_hash,
                retry: wcfg.retry,
                heartbeat_ms: wcfg.heartbeat_ms,
                ..ClientConfig::loopback(addr.clone(), m)
            };
            std::thread::spawn(move || {
                let stats = run_client(&mut w, censor, &ccfg)
                    .expect("wire loopback client failed");
                (w, stats)
            })
        })
        .collect();
    let mut pool = WirePool::new(listener, m, dim, *wcfg, ctx.spec_hash)
        .expect("wire loopback handshake failed");
    let trace =
        run_with_rules_ctx(&mut pool, cfg, server, censor, label, "wire", ctx)?;
    pool.shutdown();
    for h in handles {
        let _ = h.join().expect("wire loopback client panicked");
    }
    Ok(trace)
}
