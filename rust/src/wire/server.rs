//! The server side of the wire deployment: [`WirePool`], a
//! [`WorkerPool`] whose workers live across sockets.
//!
//! Because `WirePool` implements the same trait the in-process pools
//! do, the whole round engine ([`run_with_rules_ctx`]
//! (crate::coordinator::engine::run_with_rules_ctx)) — scheduling,
//! fault plans, SimNetwork accounting, checkpointing, the server fold
//! — runs *verbatim* over remote workers.  Reports come back in
//! worker-id order and every f64 crosses the wire as its exact bit
//! pattern, so a zero-fault loopback run is bit-identical to the
//! serial engine (invariant 6).
//!
//! Robustness machinery, per round:
//!
//! * **Idempotence** — per-connection monotonic `seq` numbers mean a
//!   chaos-duplicated or reordered frame is discarded on arrival, and
//!   a `(worker, round)` fold-dedup means a report is folded at most
//!   once.  Stale reports (an earlier round's retransmit) are always
//!   discarded, never folded.
//! * **Transactional uplinks** — each `Round` broadcast carries
//!   `acked[w]`, the highest round whose report from `w` the server
//!   accepted.  A client that transmitted round j but sees
//!   `acked < j` rolls its censor state back, so the telescope
//!   invariant (server aggregate = Σ worker θ̂ views) survives any
//!   pattern of lost uplinks.
//! * **Bounded retries** — a missing report triggers `Round`
//!   retransmits under [`RetryPolicy`] backoff; attempts are bounded,
//!   so a round always terminates.
//! * **Quorum degradation** — past the round deadline with at least
//!   `quorum` reports in hand, the round proceeds; absent workers are
//!   folded as synthesized skips and flagged for a forced uncensored
//!   transmit (PR 7's rejoin semantics) at their next active round.
//! * **Reconnect-resume** — a worker dialing in mid-run is welcomed,
//!   restored from the server's live mirror of its censor state, and
//!   force-resynced; a restarted server process rebuilds the cohort
//!   from `Hello`s and resumes from the latest checkpoint without
//!   clients restarting.

use std::time::{Duration, Instant};

use crate::coordinator::pool::{RoundInput, WorkerPool};
use crate::coordinator::worker::{WorkerRound, WorkerSnapshot};
use crate::optim::CensorDecision;
use crate::util::json::Json;

use super::chaos::{ChaosAction, ChaosSpec, LinkDir};
use super::frame::{
    bye_body, parse_hello, parse_report, parse_snapshot, round_body,
    snapshot_body, synthesized_skip, welcome_body, Frame, FrameKind,
    FrameReader, WireError,
};
use super::transport::{Conn, Listener, RetryPolicy};

/// Everything about how the wire engine behaves that belongs in the
/// manifest (reproducibility-relevant).  The listen address is
/// deliberately *not* here — where a run binds is environmental, like
/// thread counts, and lives on the CLI.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WireConfig {
    /// minimum reports per round before a deadline fold may proceed;
    /// 0 means "all M" (no degradation — the bit-identity setting)
    pub quorum: usize,
    /// round deadline in milliseconds — before it, the server waits
    /// for everyone; after it, quorum folds kick in
    pub round_deadline_ms: u32,
    /// idle-connection probe interval in milliseconds
    pub heartbeat_ms: u32,
    /// retransmit pacing
    pub retry: RetryPolicy,
    /// seeded fault injection (all-zero = off)
    pub chaos: ChaosSpec,
}

impl Default for WireConfig {
    fn default() -> Self {
        WireConfig {
            quorum: 0,
            round_deadline_ms: 5_000,
            heartbeat_ms: 1_000,
            retry: RetryPolicy::default(),
            chaos: ChaosSpec::default(),
        }
    }
}

/// Wire-level event counters — what the chaos actually did and what
/// the supervision machinery absorbed.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WireStats {
    /// `Round` frames chaos-dropped on the downlink
    pub chaos_dropped_down: u64,
    /// `Report` frames chaos-dropped at receipt
    pub chaos_dropped_up: u64,
    /// frames chaos-delayed
    pub chaos_delayed: u64,
    /// frames chaos-duplicated
    pub chaos_duplicated: u64,
    /// frames chaos-corrupted (one body bit flipped)
    pub chaos_corrupted: u64,
    /// (worker, round) partitions hit
    pub chaos_partitioned: u64,
    /// frames discarded by seq-based duplicate suppression
    pub dup_suppressed: u64,
    /// stale-round reports discarded (never folded)
    pub stale_frames: u64,
    /// frames rejected by CRC / body validation
    pub crc_rejected: u64,
    /// `Round` retransmits sent
    pub retries: u64,
    /// synthesized skips folded for workers past deadline + retries
    pub quorum_skips: u64,
    /// forced uncensored transmits requested after degradation/rejoin
    pub forced_resyncs: u64,
    /// workers re-admitted mid-run
    pub reconnects: u64,
    /// heartbeat probes sent
    pub heartbeats: u64,
    /// snapshot requests answered from the live mirror because the
    /// worker was unreachable (EF residual may be stale there)
    pub snapshot_fallbacks: u64,
    /// model payload bits written in delivered `Round` frames (64·d
    /// per frame, duplicates and retransmits charged) — the wire-side
    /// downlink ledger the trace's `downlink_bits_cum` column is
    /// checked against in zero-chaos loopback runs
    pub payload_bits_down: u64,
    /// delta payload bits of accepted Transmit reports — the wire-side
    /// uplink ledger matching the trace's `bits_cum` column
    pub payload_bits_up: u64,
}

impl WireStats {
    /// One CSV header + row (for `wire_stats.csv` artifacts).
    pub fn to_csv(&self) -> String {
        format!(
            "chaos_dropped_down,chaos_dropped_up,chaos_delayed,\
             chaos_duplicated,chaos_corrupted,chaos_partitioned,\
             dup_suppressed,stale_frames,crc_rejected,retries,\
             quorum_skips,forced_resyncs,reconnects,heartbeats,\
             snapshot_fallbacks,payload_bits_down,payload_bits_up\n\
             {},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{}\n",
            self.chaos_dropped_down,
            self.chaos_dropped_up,
            self.chaos_delayed,
            self.chaos_duplicated,
            self.chaos_corrupted,
            self.chaos_partitioned,
            self.dup_suppressed,
            self.stale_frames,
            self.crc_rejected,
            self.retries,
            self.quorum_skips,
            self.forced_resyncs,
            self.reconnects,
            self.heartbeats,
            self.snapshot_fallbacks,
            self.payload_bits_down,
            self.payload_bits_up,
        )
    }
}

/// How long the pool waits for the initial cohort of M `Hello`s.
const HANDSHAKE_WINDOW: Duration = Duration::from_secs(60);
/// Per-connection deadline for the `Hello` after an accept.
const HELLO_TIMEOUT: Duration = Duration::from_secs(5);
/// Sleep between idle collect sweeps.
const IDLE_SPIN: Duration = Duration::from_micros(200);

struct Channel {
    conn: Conn,
    reader: FrameReader,
    seq_tx: u64,
    seq_rx: u64,
    last_heard: Instant,
    last_probe: Instant,
}

impl Channel {
    fn next_seq(&mut self) -> u64 {
        self.seq_tx += 1;
        self.seq_tx
    }
}

/// A [`WorkerPool`] over sockets — see the module docs.
pub struct WirePool {
    cfg: WireConfig,
    listener: Listener,
    m: usize,
    dim: usize,
    spec_hash: Option<u64>,
    chans: Vec<Option<Channel>>,
    /// highest round whose report from worker w was accepted
    acked: Vec<u64>,
    /// worker owes a forced uncensored transmit (degradation/rejoin)
    resync: Vec<bool>,
    /// live mirror of each worker's committed censor state — what a
    /// fresh reconnect is restored from and what `per_worker_comms`
    /// reports.  `last_tx`/`transmissions` advance exactly on accepted
    /// Transmit reports, so the mirror always equals the client's
    /// committed view; the EF `residual` is the one field only a real
    /// snapshot round-trip can refresh.
    mirror: Vec<WorkerSnapshot>,
    /// latest accepted loss per worker (synthesized skips reuse it so
    /// a degraded round doesn't crater the global-loss trace)
    last_loss: Vec<f64>,
    /// current/most recent round number
    last_k: u64,
    started: bool,
    done: bool,
    stats: WireStats,
}

impl WirePool {
    /// Bind to `listener` and block until all `m` workers have said
    /// `Hello` (validated against `dim` and `spec_hash`).
    pub fn new(
        listener: Listener,
        m: usize,
        dim: usize,
        cfg: WireConfig,
        spec_hash: Option<u64>,
    ) -> Result<WirePool, WireError> {
        assert!(m > 0, "wire pool needs at least one worker");
        let now = Instant::now();
        let mut pool = WirePool {
            cfg,
            listener,
            m,
            dim,
            spec_hash,
            chans: (0..m).map(|_| None).collect(),
            acked: vec![0; m],
            resync: vec![false; m],
            mirror: (0..m)
                .map(|id| WorkerSnapshot {
                    id,
                    last_tx: vec![0.0; dim],
                    transmissions: 0,
                    residual: Vec::new(),
                })
                .collect(),
            last_loss: vec![0.0; m],
            last_k: 0,
            started: false,
            done: false,
            stats: WireStats::default(),
        };
        let deadline = now + HANDSHAKE_WINDOW;
        while pool.chans.iter().any(|c| c.is_none()) {
            if Instant::now() > deadline {
                return Err(WireError::Timeout(format!(
                    "only {}/{m} workers said hello within {}s",
                    pool.chans.iter().filter(|c| c.is_some()).count(),
                    HANDSHAKE_WINDOW.as_secs()
                )));
            }
            match pool.listener.accept_nonblocking()? {
                Some(conn) => {
                    // a bad handshake only costs that connection
                    if let Err(e) = pool.admit(conn) {
                        match e {
                            WireError::Io(_)
                            | WireError::Timeout(_)
                            | WireError::Disconnected => {}
                            other => return Err(other),
                        }
                    }
                }
                None => std::thread::sleep(Duration::from_millis(2)),
            }
        }
        pool.started = true;
        Ok(pool)
    }

    /// Effective quorum: `cfg.quorum == 0` means all M.
    fn quorum(&self) -> usize {
        if self.cfg.quorum == 0 {
            self.m
        } else {
            self.cfg.quorum.min(self.m)
        }
    }

    /// Validate a dialing connection's `Hello`, send `Welcome`, and
    /// install the channel.  Returns the admitted worker id.
    fn admit(&mut self, mut conn: Conn) -> Result<usize, WireError> {
        conn.set_read_timeout(Some(Duration::from_millis(50)))?;
        conn.set_write_timeout(Some(HELLO_TIMEOUT))?;
        let mut reader = FrameReader::new();
        let deadline = Instant::now() + HELLO_TIMEOUT;
        let hello = loop {
            if let Some(f) = reader.poll(&mut conn)? {
                if f.kind != FrameKind::Hello {
                    return Err(WireError::Protocol(format!(
                        "expected Hello, got {:?}",
                        f.kind
                    )));
                }
                break f;
            }
            if Instant::now() > deadline {
                return Err(WireError::Timeout("no Hello".into()));
            }
        };
        let msg = parse_hello(&hello.body)?;
        if msg.worker >= self.m {
            return Err(WireError::Protocol(format!(
                "worker id {} out of range (M = {})",
                msg.worker, self.m
            )));
        }
        if msg.dim != self.dim {
            return Err(WireError::Protocol(format!(
                "worker {} has dim {}, server has {}",
                msg.worker, msg.dim, self.dim
            )));
        }
        if let (Some(a), Some(b)) = (msg.spec_hash, self.spec_hash) {
            if a != b {
                return Err(WireError::Protocol(format!(
                    "worker {} manifest hash {a:016x} != server {b:016x}",
                    msg.worker
                )));
            }
        }
        let w = msg.worker;
        let reconnect = self.started;
        let mut ch = Channel {
            conn,
            reader,
            seq_tx: 0,
            seq_rx: hello.seq,
            last_heard: Instant::now(),
            last_probe: Instant::now(),
        };
        let welcome = Frame::new(
            FrameKind::Welcome,
            0,
            ch.next_seq(),
            welcome_body(self.m, self.dim, self.spec_hash),
        );
        super::frame::write_frame(&mut ch.conn, &welcome)?;
        if reconnect {
            // rejoin: re-install the mirror of the worker's committed
            // state, then require a forced uncensored transmit so its
            // θ̂ re-syncs even if the EF residual went stale
            let restore = Frame::new(
                FrameKind::Restore,
                0,
                ch.next_seq(),
                snapshot_body(&self.mirror[w]),
            );
            super::frame::write_frame(&mut ch.conn, &restore)?;
            self.resync[w] = true;
            self.stats.reconnects += 1;
        }
        // collect sweeps must never block on an idle socket
        ch.conn.set_nonblocking(true)?;
        ch.conn.set_write_timeout(Some(HELLO_TIMEOUT))?;
        self.chans[w] = Some(ch);
        Ok(w)
    }

    /// Accept any pending reconnects (non-blocking, best effort).
    fn accept_reconnects(&mut self) {
        while let Ok(Some(conn)) = self.listener.accept_nonblocking() {
            let _ = self.admit(conn);
        }
    }

    /// Send a control-plane frame (no chaos — the supervision layer is
    /// the test subject, not the harness).  A write failure drops the
    /// channel; the worker re-enters through the reconnect path.
    fn send_control(&mut self, w: usize, kind: FrameKind, round: u64, body: Json) {
        let Some(ch) = self.chans[w].as_mut() else { return };
        let f = Frame::new(kind, round, ch.next_seq(), body);
        if super::frame::write_frame(&mut ch.conn, &f).is_err() {
            self.chans[w] = None;
        }
    }

    /// Send a data-plane frame through the chaos gauntlet.
    fn send_data(
        &mut self,
        w: usize,
        kind: FrameKind,
        round: u64,
        body: &Json,
        attempt: u32,
    ) {
        if self.chans[w].is_none() {
            return;
        }
        let chaos = self.cfg.chaos;
        let mut action = ChaosAction::Deliver;
        if chaos.enabled() {
            if chaos.partitioned(w, round) {
                self.stats.chaos_partitioned += 1;
                return;
            }
            action = chaos.action(w, LinkDir::Down, round, attempt);
        }
        match action {
            ChaosAction::Drop => {
                self.stats.chaos_dropped_down += 1;
                return;
            }
            ChaosAction::Delay => {
                self.stats.chaos_delayed += 1;
                std::thread::sleep(Duration::from_millis(
                    chaos.delay_ms as u64,
                ));
            }
            ChaosAction::Duplicate => self.stats.chaos_duplicated += 1,
            ChaosAction::Corrupt => self.stats.chaos_corrupted += 1,
            ChaosAction::Deliver => {}
        }
        let Some(ch) = self.chans[w].as_mut() else { return };
        let f = Frame::new(kind, round, ch.next_seq(), body.clone());
        let mut bytes = f.encode();
        if action == ChaosAction::Corrupt {
            let body_len =
                bytes.len() - super::frame::HEADER_LEN - super::frame::CRC_LEN;
            if body_len > 0 {
                let (idx, bit) =
                    chaos.corrupt_site(w, round, attempt, body_len);
                bytes[super::frame::HEADER_LEN + idx] ^= 1 << bit;
            }
        }
        use std::io::Write;
        let sends = if action == ChaosAction::Duplicate { 2 } else { 1 };
        let mut failed = false;
        for _ in 0..sends {
            if ch.conn.write_all(&bytes).and_then(|_| ch.conn.flush()).is_err()
            {
                failed = true;
                break;
            }
        }
        if failed {
            self.chans[w] = None;
        } else if kind == FrameKind::Round {
            // wire-side downlink ledger: every delivered Round frame
            // carries the dense model; duplicates are charged too
            self.stats.payload_bits_down +=
                sends as u64 * crate::net::dense_delta_bits(self.dim);
        }
    }

    /// Drain every channel's socket into decoded, seq-deduplicated
    /// frames.  Damaged frames cost themselves; dead sockets cost the
    /// channel (the worker rejoins later).
    fn drain(&mut self) -> Vec<(usize, Frame)> {
        let mut events = Vec::new();
        for w in 0..self.m {
            let mut dead = false;
            if let Some(ch) = self.chans[w].as_mut() {
                for _ in 0..64 {
                    match ch.reader.poll(&mut ch.conn) {
                        Ok(Some(f)) => {
                            if f.seq <= ch.seq_rx {
                                self.stats.dup_suppressed += 1;
                                continue;
                            }
                            ch.seq_rx = f.seq;
                            ch.last_heard = Instant::now();
                            events.push((w, f));
                        }
                        Ok(None) => break,
                        Err(WireError::Crc { .. })
                        | Err(WireError::Body(_)) => {
                            self.stats.crc_rejected += 1;
                        }
                        Err(_) => {
                            dead = true;
                            break;
                        }
                    }
                }
            }
            if dead {
                self.chans[w] = None;
            }
        }
        events
    }

    /// Probe channels that have been silent past the heartbeat
    /// interval; a failed write surfaces dead peers early.
    fn heartbeat_sweep(&mut self) {
        let interval = Duration::from_millis(self.cfg.heartbeat_ms as u64);
        let now = Instant::now();
        for w in 0..self.m {
            let due = match self.chans[w].as_ref() {
                Some(ch) => {
                    now.duration_since(ch.last_heard) > interval
                        && now.duration_since(ch.last_probe) > interval
                }
                None => false,
            };
            if due {
                if let Some(ch) = self.chans[w].as_mut() {
                    ch.last_probe = now;
                }
                self.stats.heartbeats += 1;
                self.send_control(
                    w,
                    FrameKind::Heartbeat,
                    self.last_k,
                    super::frame::empty_body(),
                );
            }
        }
    }

    /// Process one accepted report for the current round `k`.
    fn on_report(
        &mut self,
        w: usize,
        f: &Frame,
        k: u64,
        reports: &mut [Option<WorkerRound>],
        rx_seen: &mut [u32],
    ) {
        if f.round != k {
            self.stats.stale_frames += 1;
            return;
        }
        if reports[w].is_some() {
            self.stats.dup_suppressed += 1;
            return;
        }
        rx_seen[w] += 1;
        let chaos = self.cfg.chaos;
        if chaos.enabled() {
            if chaos.partitioned(w, k) {
                self.stats.chaos_partitioned += 1;
                return;
            }
            match chaos.action(w, LinkDir::Up, k, rx_seen[w]) {
                ChaosAction::Drop => {
                    self.stats.chaos_dropped_up += 1;
                    return;
                }
                ChaosAction::Corrupt => {
                    // receive-side damage: the CRC would have caught it
                    self.stats.chaos_corrupted += 1;
                    return;
                }
                _ => {}
            }
        }
        let r = match parse_report(&f.body) {
            Ok(r) => r,
            Err(_) => {
                self.stats.crc_rejected += 1;
                return;
            }
        };
        if r.worker != w {
            self.stats.crc_rejected += 1;
            return;
        }
        // accept: this is the fold-exactly-once point
        self.acked[w] = k;
        self.last_loss[w] = r.loss;
        if r.decision == CensorDecision::Transmit {
            self.stats.payload_bits_up += r.bits;
            self.mirror[w].transmissions += 1;
            r.delta.fold_into(&mut self.mirror[w].last_tx);
            self.resync[w] = false;
        }
        reports[w] = Some(r);
    }

    /// Wire-level counters (chaos actions, retries, degradations).
    pub fn stats(&self) -> WireStats {
        self.stats
    }

    /// Send `Bye` to everyone still connected (idempotent).
    pub fn shutdown(&mut self) {
        if self.done {
            return;
        }
        self.done = true;
        for w in 0..self.m {
            let body = bye_body(self.acked[w]);
            self.send_control(w, FrameKind::Bye, self.last_k, body);
        }
        for ch in self.chans.iter().flatten() {
            ch.conn.shutdown();
        }
    }
}

impl WorkerPool for WirePool {
    fn num_workers(&self) -> usize {
        self.m
    }

    fn run_round(&mut self, input: &RoundInput) -> Vec<WorkerRound> {
        assert_eq!(input.theta.len(), self.dim, "broadcast dim");
        let k = input.k as u64;
        self.last_k = k;
        let theta_hex = crate::checkpoint::hex_f64_vec(&input.theta);
        let force_of = |pool: &WirePool, w: usize| {
            (!input.force.is_empty() && input.force[w]) || pool.resync[w]
        };
        let body_of = |pool: &WirePool, w: usize| {
            round_body(
                &theta_hex,
                input.step_sq,
                input.active[w],
                force_of(pool, w),
                pool.acked[w],
            )
        };
        // first transmission (attempt 1)
        let mut attempts = vec![1u32; self.m];
        let mut rx_seen = vec![0u32; self.m];
        for w in 0..self.m {
            if force_of(self, w) && input.active[w] {
                self.stats.forced_resyncs += 1;
            }
            let body = body_of(self, w);
            self.send_data(w, FrameKind::Round, k, &body, 1);
        }
        let start = Instant::now();
        let deadline =
            start + Duration::from_millis(self.cfg.round_deadline_ms as u64);
        let mut next_retry: Vec<Instant> = (0..self.m)
            .map(|w| start + Duration::from_millis(
                self.cfg.retry.backoff_ms(w, k, 2),
            ))
            .collect();
        let mut reports: Vec<Option<WorkerRound>> =
            (0..self.m).map(|_| None).collect();
        loop {
            self.accept_reconnects();
            let events = self.drain();
            let got_any = !events.is_empty();
            for (w, f) in events {
                match f.kind {
                    FrameKind::Report => {
                        self.on_report(w, &f, k, &mut reports, &mut rx_seen)
                    }
                    // liveness traffic and stragglers from other
                    // phases: seq/last_heard already updated in drain
                    FrameKind::Heartbeat
                    | FrameKind::Snapshot
                    | FrameKind::RestoreAck => {}
                    _ => self.stats.crc_rejected += 1,
                }
            }
            let have = reports.iter().filter(|r| r.is_some()).count();
            if have == self.m {
                break;
            }
            let now = Instant::now();
            // paced, bounded retransmits for the missing
            let mut exhausted = 0usize;
            for w in 0..self.m {
                if reports[w].is_some() {
                    continue;
                }
                if attempts[w] >= self.cfg.retry.max_attempts
                    || self.chans[w].is_none()
                {
                    exhausted += 1;
                    continue;
                }
                if now >= next_retry[w] {
                    attempts[w] += 1;
                    self.stats.retries += 1;
                    let body = body_of(self, w);
                    self.send_data(w, FrameKind::Round, k, &body, attempts[w]);
                    next_retry[w] = now
                        + Duration::from_millis(
                            self.cfg.retry.backoff_ms(w, k, attempts[w] + 1),
                        );
                }
            }
            let past_deadline = now >= deadline;
            if past_deadline && have >= self.quorum() {
                break;
            }
            // every missing worker is out of attempts or offline and
            // the deadline has passed: degrade rather than hang, even
            // below quorum — bounded progress beats a stuck cohort
            if past_deadline && exhausted == self.m - have {
                break;
            }
            self.heartbeat_sweep();
            if !got_any {
                std::thread::sleep(IDLE_SPIN);
            }
        }
        // degrade the missing: fold a synthesized skip and require a
        // forced uncensored transmit when they next compute
        (0..self.m)
            .map(|w| match reports[w].take() {
                Some(r) => r,
                None => {
                    self.stats.quorum_skips += 1;
                    self.resync[w] = true;
                    let mut r = synthesized_skip(w);
                    r.loss = self.last_loss[w];
                    r
                }
            })
            .collect()
    }

    fn per_worker_comms(&mut self) -> Vec<usize> {
        self.mirror.iter().map(|s| s.transmissions).collect()
    }

    fn snapshots(&mut self) -> Vec<WorkerSnapshot> {
        // a real snapshot round-trip per worker: the EF residual lives
        // only client-side, so the mirror alone is not checkpoint-grade
        let deadline_each =
            Duration::from_millis(self.cfg.round_deadline_ms as u64);
        for w in 0..self.m {
            self.send_control(
                w,
                FrameKind::SnapshotReq,
                self.last_k,
                super::frame::empty_body(),
            );
            if self.chans[w].is_none() {
                self.stats.snapshot_fallbacks += 1;
                continue;
            }
            let deadline = Instant::now() + deadline_each;
            let mut got = false;
            'wait: while Instant::now() < deadline {
                let events = self.drain();
                let idle = events.is_empty();
                for (ew, f) in events {
                    if f.kind == FrameKind::Snapshot && ew == w {
                        if let Ok(s) = parse_snapshot(&f.body) {
                            if s.id == w && s.last_tx.len() == self.dim {
                                self.mirror[w] = s;
                                got = true;
                                break 'wait;
                            }
                        }
                        self.stats.crc_rejected += 1;
                    } else if f.kind == FrameKind::Report {
                        self.stats.stale_frames += 1;
                    }
                }
                if self.chans[w].is_none() {
                    break;
                }
                if idle {
                    std::thread::sleep(IDLE_SPIN);
                }
            }
            if !got {
                self.stats.snapshot_fallbacks += 1;
            }
        }
        self.mirror.clone()
    }

    fn restore(&mut self, snaps: &[WorkerSnapshot]) {
        assert_eq!(snaps.len(), self.m, "snapshot count");
        let deadline_each =
            Duration::from_millis(self.cfg.round_deadline_ms as u64);
        for (w, s) in snaps.iter().enumerate() {
            self.mirror[w] = s.clone();
            self.acked[w] = 0;
            self.resync[w] = false;
            self.send_control(
                w,
                FrameKind::Restore,
                0,
                snapshot_body(s),
            );
            if self.chans[w].is_none() {
                continue;
            }
            let deadline = Instant::now() + deadline_each;
            'wait: while Instant::now() < deadline {
                let events = self.drain();
                let idle = events.is_empty();
                for (ew, f) in events {
                    if f.kind == FrameKind::RestoreAck && ew == w {
                        break 'wait;
                    } else if f.kind == FrameKind::Report {
                        self.stats.stale_frames += 1;
                    }
                }
                if self.chans[w].is_none() {
                    break;
                }
                if idle {
                    std::thread::sleep(IDLE_SPIN);
                }
            }
        }
    }

    fn name(&self) -> &'static str {
        "wire"
    }
}

impl Drop for WirePool {
    fn drop(&mut self) {
        self.shutdown();
    }
}
