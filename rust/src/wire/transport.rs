//! Transport selection (TCP vs Unix-domain sockets) and the retry /
//! backoff policy, all on `std::net` — no async runtime.
//!
//! Both socket families are wrapped behind [`Listener`] / [`Conn`]
//! enums so the rest of the wire module is family-agnostic.  TCP gets
//! `TCP_NODELAY` (frames are small and latency-bound); UDS is gated
//! `#[cfg(unix)]` and rejected with a clear error elsewhere.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::time::Duration;

use crate::rng::SplitMix64;

/// Where a server listens / a worker connects — parsed from
/// `tcp:HOST:PORT` or `uds:/path/to.sock`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum TransportSpec {
    /// TCP address, e.g. `127.0.0.1:7700`
    Tcp(String),
    /// Unix-domain socket path (unix only)
    Uds(PathBuf),
}

impl TransportSpec {
    /// Parse `tcp:HOST:PORT` / `uds:PATH`.
    pub fn parse(s: &str) -> Result<TransportSpec, String> {
        if let Some(addr) = s.strip_prefix("tcp:") {
            if addr.rsplit_once(':').is_none() {
                return Err(format!(
                    "tcp transport '{addr}' is not HOST:PORT"
                ));
            }
            Ok(TransportSpec::Tcp(addr.to_string()))
        } else if let Some(path) = s.strip_prefix("uds:") {
            if path.is_empty() {
                return Err("uds transport needs a socket path".into());
            }
            Ok(TransportSpec::Uds(PathBuf::from(path)))
        } else {
            Err(format!(
                "transport '{s}' must start with 'tcp:' or 'uds:'"
            ))
        }
    }
}

impl std::fmt::Display for TransportSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TransportSpec::Tcp(a) => write!(f, "tcp:{a}"),
            TransportSpec::Uds(p) => write!(f, "uds:{}", p.display()),
        }
    }
}

#[cfg(not(unix))]
fn uds_unsupported() -> std::io::Error {
    std::io::Error::new(
        std::io::ErrorKind::Unsupported,
        "uds transport is only available on unix",
    )
}

/// A bound server socket of either family.
pub enum Listener {
    /// TCP listener
    Tcp(TcpListener),
    /// UDS listener (unix only)
    #[cfg(unix)]
    Uds(UnixListener),
}

impl Listener {
    /// Bind the spec'd address.
    pub fn bind(spec: &TransportSpec) -> std::io::Result<Listener> {
        match spec {
            TransportSpec::Tcp(addr) => {
                Ok(Listener::Tcp(TcpListener::bind(addr)?))
            }
            #[cfg(unix)]
            TransportSpec::Uds(path) => {
                // a stale socket file from a previous run blocks bind
                let _ = std::fs::remove_file(path);
                Ok(Listener::Uds(UnixListener::bind(path)?))
            }
            #[cfg(not(unix))]
            TransportSpec::Uds(_) => Err(uds_unsupported()),
        }
    }

    /// Bind an ephemeral loopback TCP port and return the spec a
    /// client should dial — the in-process loopback engine's listener.
    pub fn bind_loopback() -> std::io::Result<(Listener, TransportSpec)> {
        let l = TcpListener::bind("127.0.0.1:0")?;
        let addr = l.local_addr()?;
        Ok((Listener::Tcp(l), TransportSpec::Tcp(addr.to_string())))
    }

    /// Accept one pending connection without blocking; `None` when
    /// nobody is dialing right now.  The accepted stream is switched
    /// back to blocking mode (callers set read deadlines per use).
    pub fn accept_nonblocking(&self) -> std::io::Result<Option<Conn>> {
        match self {
            Listener::Tcp(l) => {
                l.set_nonblocking(true)?;
                match l.accept() {
                    Ok((s, _)) => {
                        s.set_nonblocking(false)?;
                        s.set_nodelay(true)?;
                        Ok(Some(Conn::Tcp(s)))
                    }
                    Err(e)
                        if e.kind() == std::io::ErrorKind::WouldBlock =>
                    {
                        Ok(None)
                    }
                    Err(e) => Err(e),
                }
            }
            #[cfg(unix)]
            Listener::Uds(l) => {
                l.set_nonblocking(true)?;
                match l.accept() {
                    Ok((s, _)) => {
                        s.set_nonblocking(false)?;
                        Ok(Some(Conn::Uds(s)))
                    }
                    Err(e)
                        if e.kind() == std::io::ErrorKind::WouldBlock =>
                    {
                        Ok(None)
                    }
                    Err(e) => Err(e),
                }
            }
        }
    }

    /// Block until one connection arrives.
    pub fn accept_blocking(&self) -> std::io::Result<Conn> {
        match self {
            Listener::Tcp(l) => {
                l.set_nonblocking(false)?;
                let (s, _) = l.accept()?;
                s.set_nodelay(true)?;
                Ok(Conn::Tcp(s))
            }
            #[cfg(unix)]
            Listener::Uds(l) => {
                l.set_nonblocking(false)?;
                let (s, _) = l.accept()?;
                Ok(Conn::Uds(s))
            }
        }
    }
}

/// One established connection of either family.
pub enum Conn {
    /// TCP stream
    Tcp(TcpStream),
    /// UDS stream (unix only)
    #[cfg(unix)]
    Uds(UnixStream),
}

impl Conn {
    /// Dial the spec'd address (one attempt — callers wrap this in
    /// [`RetryPolicy`]-paced loops).
    pub fn connect(spec: &TransportSpec) -> std::io::Result<Conn> {
        match spec {
            TransportSpec::Tcp(addr) => {
                let s = TcpStream::connect(addr)?;
                s.set_nodelay(true)?;
                Ok(Conn::Tcp(s))
            }
            #[cfg(unix)]
            TransportSpec::Uds(path) => {
                Ok(Conn::Uds(UnixStream::connect(path)?))
            }
            #[cfg(not(unix))]
            TransportSpec::Uds(_) => Err(uds_unsupported()),
        }
    }

    /// Set the read deadline (None = block forever).
    pub fn set_read_timeout(
        &self,
        d: Option<Duration>,
    ) -> std::io::Result<()> {
        match self {
            Conn::Tcp(s) => s.set_read_timeout(d),
            #[cfg(unix)]
            Conn::Uds(s) => s.set_read_timeout(d),
        }
    }

    /// Switch non-blocking mode (the server's collect sweeps poll all
    /// channels without ever parking on an idle one).
    pub fn set_nonblocking(&self, nb: bool) -> std::io::Result<()> {
        match self {
            Conn::Tcp(s) => s.set_nonblocking(nb),
            #[cfg(unix)]
            Conn::Uds(s) => s.set_nonblocking(nb),
        }
    }

    /// Set the write deadline (None = block forever).
    pub fn set_write_timeout(
        &self,
        d: Option<Duration>,
    ) -> std::io::Result<()> {
        match self {
            Conn::Tcp(s) => s.set_write_timeout(d),
            #[cfg(unix)]
            Conn::Uds(s) => s.set_write_timeout(d),
        }
    }

    /// Shut the connection down in both directions (best effort).
    pub fn shutdown(&self) {
        match self {
            Conn::Tcp(s) => {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
            #[cfg(unix)]
            Conn::Uds(s) => {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
        }
    }
}

impl Read for Conn {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            Conn::Uds(s) => s.read(buf),
        }
    }
}

impl Write for Conn {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            Conn::Uds(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Conn::Tcp(s) => s.flush(),
            #[cfg(unix)]
            Conn::Uds(s) => s.flush(),
        }
    }
}

/// Bounded exponential backoff with seeded jitter.  The jitter is a
/// pure function of `(jitter_seed, worker, round, attempt)`, so retry
/// pacing — like everything else on this wire — replays identically.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RetryPolicy {
    /// total send attempts per logical message (first send included);
    /// once exhausted the server degrades the worker for the round
    pub max_attempts: u32,
    /// backoff base in milliseconds (attempt n waits ~base·2ⁿ⁻¹)
    pub base_ms: u32,
    /// jitter stream seed
    pub jitter_seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { max_attempts: 5, base_ms: 10, jitter_seed: 0x1077 }
    }
}

/// Backoff ceiling — one retry never sleeps longer than this.
pub const BACKOFF_CAP_MS: u64 = 2_000;

impl RetryPolicy {
    /// Milliseconds to wait before retry number `attempt` (2-based:
    /// the first send is attempt 1 and waits nothing).
    pub fn backoff_ms(&self, worker: usize, round: u64, attempt: u32) -> u64 {
        if attempt <= 1 {
            return 0;
        }
        let exp = (self.base_ms as u64)
            .saturating_mul(1u64 << (attempt - 2).min(16));
        let mut g = SplitMix64::new(
            self.jitter_seed
                ^ (worker as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ round.wrapping_mul(0xC2B2_AE3D_27D4_EB4F)
                ^ attempt as u64,
        );
        let jitter = g.next_u64() % (self.base_ms as u64 + 1);
        (exp + jitter).min(BACKOFF_CAP_MS)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transport_specs_parse_and_round_trip() {
        let t = TransportSpec::parse("tcp:127.0.0.1:7700").unwrap();
        assert_eq!(t, TransportSpec::Tcp("127.0.0.1:7700".into()));
        assert_eq!(t.to_string(), "tcp:127.0.0.1:7700");
        let u = TransportSpec::parse("uds:/tmp/chb.sock").unwrap();
        assert_eq!(u, TransportSpec::Uds(PathBuf::from("/tmp/chb.sock")));
        assert_eq!(u.to_string(), "uds:/tmp/chb.sock");
        assert!(TransportSpec::parse("http:nope").is_err());
        assert!(TransportSpec::parse("tcp:noport").is_err());
        assert!(TransportSpec::parse("uds:").is_err());
    }

    #[test]
    fn backoff_grows_is_jittered_and_capped() {
        let r = RetryPolicy::default();
        assert_eq!(r.backoff_ms(0, 1, 1), 0);
        let b2 = r.backoff_ms(0, 1, 2);
        let b4 = r.backoff_ms(0, 1, 4);
        assert!(b2 >= 10 && b2 <= 20, "attempt 2 ~ base: {b2}");
        assert!(b4 >= 40 && b4 <= 50, "attempt 4 ~ 4·base: {b4}");
        assert!(r.backoff_ms(0, 1, 40) <= BACKOFF_CAP_MS);
        // deterministic
        assert_eq!(r.backoff_ms(3, 7, 3), r.backoff_ms(3, 7, 3));
        // jitter decorrelates workers
        let mut differs = false;
        for w in 0..8 {
            if r.backoff_ms(w, 1, 2) != r.backoff_ms(w + 1, 1, 2) {
                differs = true;
            }
        }
        assert!(differs);
    }

    #[test]
    fn loopback_tcp_round_trips_a_frame() {
        use crate::util::json::Json;
        use crate::wire::frame::{
            empty_body, write_frame, Frame, FrameKind, FrameReader,
        };
        let (listener, spec) = Listener::bind_loopback().unwrap();
        let h = std::thread::spawn(move || {
            let mut c = Conn::connect(&spec).unwrap();
            let f = Frame::new(FrameKind::Heartbeat, 3, 1, empty_body());
            write_frame(&mut c, &f).unwrap();
            c
        });
        let mut server_side = listener.accept_blocking().unwrap();
        server_side
            .set_read_timeout(Some(Duration::from_secs(5)))
            .unwrap();
        let mut reader = FrameReader::new();
        let f = loop {
            if let Some(f) = reader.poll(&mut server_side).unwrap() {
                break f;
            }
        };
        assert_eq!(f.kind, FrameKind::Heartbeat);
        assert_eq!(f.round, 3);
        assert_eq!(f.seq, 1);
        assert_eq!(f.body, Json::Obj(Default::default()));
        drop(h.join().unwrap());
    }
}
