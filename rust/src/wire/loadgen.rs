//! Closed-loop wire throughput harness: hundreds of loopback clients
//! against one [`WirePool`], measuring rounds/sec, fold throughput,
//! and tail latency.
//!
//! The pool is driven directly with hand-built [`RoundInput`]s (no
//! [`crate::coordinator::Server`]): the point is to meter the
//! *transport* — frame encode/decode, chaos gauntlet, collect sweeps —
//! not the optimizer.  Workers run a tiny quadratic backend under
//! [`NeverCensor`], so every round folds all M reports (worst-case
//! uplink load for the protocol).

use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::pool::{RoundInput, WorkerPool};
use crate::coordinator::worker::{GradientBackend, Worker};
use crate::optim::{CensorRule, NeverCensor};
use crate::util::json::Json;

use super::client::{run_client, ClientConfig};
use super::server::{WireConfig, WirePool, WireStats};
use super::transport::Listener;
use super::WireError;

/// Loadgen shape: how many clients, how many rounds, what dimension.
#[derive(Clone, Copy, Debug)]
pub struct LoadgenConfig {
    /// concurrent loopback clients M
    pub workers: usize,
    /// rounds to drive
    pub rounds: usize,
    /// parameter dimension d (payload size knob: ~16·d bytes/frame)
    pub dim: usize,
    /// simulated population size the cohort stands in for (0 = none).
    /// The cohort presets (`chb-fed loadgen --preset cohort-10k`)
    /// drive `workers` concurrent clients as one sampled cohort out of
    /// this many devices; the value only renames the bench rows —
    /// wire load is set by `workers`, which is the per-round fan-in a
    /// population server actually sees.
    pub population: u64,
    /// wire behavior (quorum, deadlines, chaos, …)
    pub wire: WireConfig,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            workers: 100,
            rounds: 50,
            dim: 50,
            population: 0,
            wire: WireConfig::default(),
        }
    }
}

/// What a loadgen run measured.
#[derive(Clone, Debug)]
pub struct LoadgenReport {
    /// clients driven
    pub workers: usize,
    /// rounds completed
    pub rounds: usize,
    /// parameter dimension
    pub dim: usize,
    /// simulated population the cohort stood in for (0 = none)
    pub population: u64,
    /// wall-clock for the full drive (seconds)
    pub elapsed_s: f64,
    /// rounds per second (closed loop)
    pub rounds_per_sec: f64,
    /// report folds per second (M × rounds/sec)
    pub folds_per_sec: f64,
    /// median per-round latency (ns)
    pub median_ns: u64,
    /// median absolute deviation of per-round latency (ns)
    pub mad_ns: u64,
    /// 99th-percentile per-round latency (ns)
    pub p99_ns: u64,
    /// fastest round (ns)
    pub min_ns: u64,
    /// slowest round (ns)
    pub max_ns: u64,
    /// server-side wire counters
    pub stats: WireStats,
}

impl LoadgenReport {
    /// Rows in the `BENCH_hotpath.json` schema (`tools/bench_diff.py`
    /// consumes these): one row for the median round latency, one for
    /// the p99 tail.
    pub fn bench_rows(&self) -> Vec<Json> {
        // cohort-preset runs key their rows on the population shape
        // (the claim being benchmarked), plain runs on the fan-in
        let base = if self.population > 0 {
            format!(
                "wire_loadgen_pop{}_cohort{}_d{}_round",
                self.population, self.workers, self.dim
            )
        } else {
            format!("wire_loadgen_m{}_d{}_round", self.workers, self.dim)
        };
        let row = |name: String, center: u64, spread: u64| {
            let mut o = std::collections::BTreeMap::new();
            o.insert("name".to_string(), Json::Str(name));
            o.insert("median_ns".to_string(), Json::Num(center as f64));
            o.insert("mad_ns".to_string(), Json::Num(spread as f64));
            o.insert("iters".to_string(), Json::Num(self.rounds as f64));
            o.insert("samples".to_string(), Json::Num(self.rounds as f64));
            o.insert("min_ns".to_string(), Json::Num(self.min_ns as f64));
            o.insert("max_ns".to_string(), Json::Num(self.max_ns as f64));
            Json::Obj(o)
        };
        vec![
            row(base.clone(), self.median_ns, self.mad_ns),
            row(format!("{base}_p99"), self.p99_ns, self.mad_ns),
        ]
    }

    /// Human-readable one-screen summary.
    pub fn summary(&self) -> String {
        let shape = if self.population > 0 {
            format!(
                "population={} cohort={}",
                self.population, self.workers
            )
        } else {
            format!("M={}", self.workers)
        };
        format!(
            "wire loadgen: {shape} d={} rounds={}\n\
             elapsed        {:.3} s\n\
             rounds/sec     {:.1}\n\
             folds/sec      {:.1}\n\
             round p50      {:.3} ms\n\
             round p99      {:.3} ms\n\
             round min/max  {:.3} / {:.3} ms\n\
             retries={} quorum_skips={} reconnects={} dup_suppressed={}",
            self.dim,
            self.rounds,
            self.elapsed_s,
            self.rounds_per_sec,
            self.folds_per_sec,
            self.median_ns as f64 / 1e6,
            self.p99_ns as f64 / 1e6,
            self.min_ns as f64 / 1e6,
            self.max_ns as f64 / 1e6,
            self.stats.retries,
            self.stats.quorum_skips,
            self.stats.reconnects,
            self.stats.dup_suppressed,
        )
    }
}

/// f_m(θ) = ½‖θ − c_m‖² — cheap, per-worker-distinct gradients.
struct Quad {
    c: Vec<f64>,
}

impl GradientBackend for Quad {
    fn dim(&self) -> usize {
        self.c.len()
    }

    fn grad_loss_into(&mut self, theta: &[f64], grad: &mut [f64]) -> f64 {
        let mut loss = 0.0;
        for ((g, t), c) in grad.iter_mut().zip(theta).zip(&self.c) {
            *g = t - c;
            loss += 0.5 * (t - c) * (t - c);
        }
        loss
    }
}

fn percentile(sorted: &[u64], p: usize) -> u64 {
    let idx = (sorted.len() * p / 100).min(sorted.len() - 1);
    sorted[idx]
}

/// Drive the loadgen and measure.
pub fn run_loadgen(cfg: &LoadgenConfig) -> Result<LoadgenReport, WireError> {
    let m = cfg.workers.max(1);
    let dim = cfg.dim.max(1);
    let rounds = cfg.rounds.max(1);
    let (listener, addr) = Listener::bind_loopback()?;
    let censor: Arc<dyn CensorRule> = Arc::new(NeverCensor);
    let handles: Vec<_> = (0..m)
        .map(|id| {
            let censor = Arc::clone(&censor);
            let ccfg = ClientConfig {
                retry: cfg.wire.retry,
                heartbeat_ms: cfg.wire.heartbeat_ms,
                ..ClientConfig::loopback(addr.clone(), m)
            };
            let c = vec![(id + 1) as f64 / m as f64; dim];
            std::thread::spawn(move || {
                let mut w = Worker::new(id, Box::new(Quad { c }));
                run_client(&mut w, censor, &ccfg)
                    .expect("loadgen client failed")
            })
        })
        .collect();
    let mut pool = WirePool::new(listener, m, dim, cfg.wire, None)?;
    let active = Arc::new(vec![true; m]);
    let force: Arc<Vec<bool>> = Arc::new(Vec::new());
    let mut samples = Vec::with_capacity(rounds);
    let t0 = Instant::now();
    for k in 1..=rounds {
        let theta = Arc::new(vec![1.0 / k as f64; dim]);
        let input = RoundInput {
            k,
            theta,
            step_sq: 1.0,
            active: Arc::clone(&active),
            force: Arc::clone(&force),
            censor: Arc::clone(&censor),
        };
        let t = Instant::now();
        let reports = pool.run_round(&input);
        samples.push(t.elapsed().as_nanos() as u64);
        debug_assert_eq!(reports.len(), m);
    }
    let elapsed_s = t0.elapsed().as_secs_f64();
    let stats = pool.stats();
    pool.shutdown();
    for h in handles {
        let _ = h.join().expect("loadgen client panicked");
    }
    samples.sort_unstable();
    let median_ns = percentile(&samples, 50);
    let mut dev: Vec<u64> =
        samples.iter().map(|&s| s.abs_diff(median_ns)).collect();
    dev.sort_unstable();
    let mad_ns = percentile(&dev, 50);
    Ok(LoadgenReport {
        workers: m,
        rounds,
        dim,
        population: cfg.population,
        elapsed_s,
        rounds_per_sec: rounds as f64 / elapsed_s.max(1e-9),
        folds_per_sec: (m * rounds) as f64 / elapsed_s.max(1e-9),
        median_ns,
        mad_ns,
        p99_ns: percentile(&samples, 99),
        min_ns: samples[0],
        max_ns: samples[samples.len() - 1],
        stats,
    })
}
