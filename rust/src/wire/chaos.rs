//! Seeded fault injection for the wire transport.
//!
//! Every decision — drop this frame, delay it, duplicate it, corrupt
//! one body byte, partition this worker — is a pure function of
//! `(seed, worker, direction, round, attempt)`, hashed exactly the way
//! [`crate::coordinator::fault::FaultPlan`] derives its per-(worker,
//! round) streams.  Nothing is sampled from wall-clock state, so a
//! chaos schedule replays identically run after run: the *trace* of a
//! seeded chaos run is deterministic even though the wire chatter
//! (retry timing, poll interleaving) is not.
//!
//! Chaos applies to the data plane only (`Round` broadcasts and
//! `Report` uplinks).  Control frames — handshake, snapshot, restore,
//! heartbeat, bye — are delivered faithfully: fault tolerance of the
//! *round protocol* is what is under test, not the test harness
//! itself.

use crate::rng::SplitMix64;

/// Fault probabilities and the schedule seed.  All-zero probabilities
/// (the default) disable injection entirely — the transport then
/// writes frames straight through, which is the configuration the
/// bit-identity pin runs under.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ChaosSpec {
    /// probability a data frame is silently dropped
    pub drop: f64,
    /// probability a data frame is delayed by [`ChaosSpec::delay_ms`]
    pub delay_prob: f64,
    /// delay applied to delayed frames, in milliseconds
    pub delay_ms: u32,
    /// probability a data frame is sent twice (same seq — the
    /// receiver's duplicate suppression must absorb it)
    pub duplicate: f64,
    /// probability one body byte of a data frame is bit-flipped (the
    /// receiver's CRC must reject it)
    pub corrupt: f64,
    /// probability a (worker, round) link is partitioned — both
    /// directions drop everything for that round
    pub partition: f64,
    /// schedule seed
    pub seed: u64,
}

impl Default for ChaosSpec {
    fn default() -> Self {
        ChaosSpec {
            drop: 0.0,
            delay_prob: 0.0,
            delay_ms: 5,
            duplicate: 0.0,
            corrupt: 0.0,
            partition: 0.0,
            seed: 0xC405,
        }
    }
}

/// Which way a data frame is travelling.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LinkDir {
    /// server → worker (`Round` broadcast)
    Down,
    /// worker → server (`Report` uplink)
    Up,
}

/// The verdict for one (frame, attempt).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChaosAction {
    /// send faithfully
    Deliver,
    /// do not send at all
    Drop,
    /// sleep [`ChaosSpec::delay_ms`], then send
    Delay,
    /// send the identical bytes twice
    Duplicate,
    /// flip one body bit, then send
    Corrupt,
}

// Direction salts keep the up and down streams independent; the
// worker/round mixing constants match FaultPlan's.
const SALT_DOWN: u64 = 0x00D0_77AE;
const SALT_UP: u64 = 0x001B_55C4;
const SALT_PART: u64 = 0x00A7_0A17;

impl ChaosSpec {
    /// Whether any injection is configured at all.
    pub fn enabled(&self) -> bool {
        self.drop > 0.0
            || self.delay_prob > 0.0
            || self.duplicate > 0.0
            || self.corrupt > 0.0
            || self.partition > 0.0
    }

    fn draw(&self, salt: u64, worker: usize, round: u64, attempt: u32) -> f64 {
        let mut g = SplitMix64::new(
            self.seed
                ^ salt
                ^ (worker as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ round.wrapping_mul(0xC2B2_AE3D_27D4_EB4F)
                ^ (attempt as u64).wrapping_mul(0x1656_67B1_9E37_79F9),
        );
        (g.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// The verdict for one data frame.  `attempt` numbers retransmits
    /// of the same logical message (1-based), so a retry draws a fresh
    /// verdict — bounded retries eventually punch through any
    /// sub-certain drop rate, deterministically.
    pub fn action(
        &self,
        worker: usize,
        dir: LinkDir,
        round: u64,
        attempt: u32,
    ) -> ChaosAction {
        let salt = match dir {
            LinkDir::Down => SALT_DOWN,
            LinkDir::Up => SALT_UP,
        };
        let u = self.draw(salt, worker, round, attempt);
        let mut edge = self.drop;
        if u < edge {
            return ChaosAction::Drop;
        }
        edge += self.delay_prob;
        if u < edge {
            return ChaosAction::Delay;
        }
        edge += self.duplicate;
        if u < edge {
            return ChaosAction::Duplicate;
        }
        edge += self.corrupt;
        if u < edge {
            return ChaosAction::Corrupt;
        }
        ChaosAction::Deliver
    }

    /// Whether the (worker, round) link is partitioned — checked
    /// before per-frame actions; a partition silences both directions
    /// for the whole round regardless of retries.
    pub fn partitioned(&self, worker: usize, round: u64) -> bool {
        self.partition > 0.0
            && self.draw(SALT_PART, worker, round, 0) < self.partition
    }

    /// Deterministically pick a body byte to bit-flip for a Corrupt
    /// verdict: returns `(byte_index_within_body, bit)`.
    pub fn corrupt_site(
        &self,
        worker: usize,
        round: u64,
        attempt: u32,
        body_len: usize,
    ) -> (usize, u8) {
        let mut g = SplitMix64::new(
            self.seed
                ^ 0xC0_44_0B_7E
                ^ (worker as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ round.wrapping_mul(0xC2B2_AE3D_27D4_EB4F)
                ^ (attempt as u64).wrapping_mul(0x1656_67B1_9E37_79F9),
        );
        let idx = (g.next_u64() % body_len.max(1) as u64) as usize;
        let bit = (g.next_u64() % 8) as u8;
        (idx, bit)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn actions_are_pure_functions_of_the_key() {
        let c = ChaosSpec {
            drop: 0.2,
            delay_prob: 0.1,
            duplicate: 0.1,
            corrupt: 0.1,
            partition: 0.1,
            ..ChaosSpec::default()
        };
        for w in 0..4 {
            for k in 1..40u64 {
                for a in 1..4 {
                    assert_eq!(
                        c.action(w, LinkDir::Down, k, a),
                        c.action(w, LinkDir::Down, k, a)
                    );
                    assert_eq!(
                        c.action(w, LinkDir::Up, k, a),
                        c.action(w, LinkDir::Up, k, a)
                    );
                }
                assert_eq!(c.partitioned(w, k), c.partitioned(w, k));
            }
        }
    }

    #[test]
    fn directions_and_attempts_draw_independent_streams() {
        let c = ChaosSpec { drop: 0.5, ..ChaosSpec::default() };
        let mut differs_dir = false;
        let mut differs_attempt = false;
        for k in 1..200u64 {
            if c.action(0, LinkDir::Down, k, 1) != c.action(0, LinkDir::Up, k, 1)
            {
                differs_dir = true;
            }
            if c.action(0, LinkDir::Down, k, 1)
                != c.action(0, LinkDir::Down, k, 2)
            {
                differs_attempt = true;
            }
        }
        assert!(differs_dir, "up/down streams should decorrelate");
        assert!(differs_attempt, "retries should draw fresh verdicts");
    }

    #[test]
    fn zero_spec_always_delivers() {
        let c = ChaosSpec::default();
        assert!(!c.enabled());
        for k in 1..100u64 {
            assert_eq!(c.action(0, LinkDir::Up, k, 1), ChaosAction::Deliver);
            assert!(!c.partitioned(0, k));
        }
    }

    #[test]
    fn rates_land_near_their_probabilities() {
        let c = ChaosSpec { drop: 0.3, ..ChaosSpec::default() };
        let n = 10_000;
        let dropped = (1..=n)
            .filter(|&k| c.action(1, LinkDir::Up, k, 1) == ChaosAction::Drop)
            .count();
        let rate = dropped as f64 / n as f64;
        assert!((rate - 0.3).abs() < 0.02, "drop rate {rate} far from 0.3");
    }
}
