//! The worker side of the wire deployment: one [`Worker`] kept in
//! lock-step with a remote server by [`run_client`].
//!
//! The client is a pure responder — it never initiates protocol state,
//! it reacts to frames in arrival order.  Robustness rests on three
//! mechanisms:
//!
//! * **Transactional rounds.**  Before computing a round the client
//!   snapshots its censor state.  If the round transmits, the
//!   (round, snapshot) pair stays *pending* until a later `Round`
//!   frame's `acked` field proves the server accepted the report —
//!   otherwise the snapshot is rolled back, exactly cancelling the θ̂
//!   advance the lost uplink would have left dangling.  Skips mutate
//!   nothing, so they need no transaction.
//! * **Idempotent retransmits.**  A repeated `Round` for the round
//!   just computed is answered from a cached report body (fresh seq,
//!   identical payload bits), so server retries can never double-run
//!   a gradient; frames with non-advancing seq numbers are dropped.
//! * **Reconnect.**  On any stream-level failure the client redials
//!   under bounded seeded backoff, re-runs the `Hello`/`Welcome`
//!   handshake, and lets the server's `Restore` frame re-install its
//!   committed state (followed by a forced uncensored transmit,
//!   PR 7's rejoin semantics).  A server process restart looks to the
//!   client like one more reconnect.

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coordinator::pool::{run_worker_round, RoundInput};
use crate::coordinator::worker::{Worker, WorkerSnapshot};
use crate::optim::{CensorDecision, CensorRule};
use crate::util::json::Json;

use super::frame::{
    hello_body, parse_bye, parse_round, parse_snapshot, parse_welcome,
    report_body, snapshot_body, write_frame, Frame, FrameKind, FrameReader,
    WireError,
};
use super::transport::{Conn, RetryPolicy, TransportSpec};

/// Client-side knobs.  `m` and `spec_hash` are validated against the
/// server's `Welcome`, so a worker can never join the wrong cohort.
#[derive(Clone, Debug)]
pub struct ClientConfig {
    /// where the server listens
    pub transport: TransportSpec,
    /// expected cohort size M
    pub m: usize,
    /// expected manifest hash (None skips the check)
    pub spec_hash: Option<u64>,
    /// backoff pacing for dial retries
    pub retry: RetryPolicy,
    /// idle probe interval (milliseconds)
    pub heartbeat_ms: u32,
    /// redial budget across the whole run — each successful handshake
    /// refunds nothing, so this bounds total tolerated failures
    pub max_reconnects: u32,
}

impl ClientConfig {
    /// Sensible defaults for a loopback deployment.
    pub fn loopback(transport: TransportSpec, m: usize) -> ClientConfig {
        ClientConfig {
            transport,
            m,
            spec_hash: None,
            retry: RetryPolicy::default(),
            heartbeat_ms: 1_000,
            max_reconnects: 100,
        }
    }
}

/// What happened on the client side of a run.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ClientStats {
    /// rounds computed (scheduled or observing)
    pub rounds: u64,
    /// cached-report retransmissions served
    pub retransmits: u64,
    /// pending transmits rolled back because the server never acked
    pub rollbacks: u64,
    /// pending transmits committed
    pub commits: u64,
    /// dials after the first (server restarts, network blips)
    pub reconnects: u64,
    /// damaged frames discarded by CRC / body validation
    pub crc_rejected: u64,
    /// frames dropped by seq-based duplicate suppression
    pub dup_suppressed: u64,
}

/// Timeout for the `Welcome` after a `Hello`.
const WELCOME_TIMEOUT: Duration = Duration::from_secs(5);
/// Read-poll granularity on the established connection.
const POLL_TIMEOUT: Duration = Duration::from_millis(50);

struct Session {
    conn: Conn,
    reader: FrameReader,
    seq_tx: u64,
    seq_rx: u64,
}

impl Session {
    fn send(
        &mut self,
        kind: FrameKind,
        round: u64,
        body: Json,
    ) -> Result<(), WireError> {
        self.seq_tx += 1;
        let f = Frame::new(kind, round, self.seq_tx, body);
        write_frame(&mut self.conn, &f)
    }
}

/// One dial + handshake attempt.
fn dial(
    worker_id: usize,
    dim: usize,
    cfg: &ClientConfig,
) -> Result<Session, WireError> {
    let conn = Conn::connect(&cfg.transport)?;
    conn.set_read_timeout(Some(POLL_TIMEOUT))?;
    conn.set_write_timeout(Some(WELCOME_TIMEOUT))?;
    let mut s = Session { conn, reader: FrameReader::new(), seq_tx: 0, seq_rx: 0 };
    s.send(
        FrameKind::Hello,
        0,
        hello_body(worker_id, dim, cfg.spec_hash),
    )?;
    let deadline = Instant::now() + WELCOME_TIMEOUT;
    loop {
        if let Some(f) = s.reader.poll(&mut s.conn)? {
            if f.kind != FrameKind::Welcome {
                return Err(WireError::Protocol(format!(
                    "expected Welcome, got {:?}",
                    f.kind
                )));
            }
            let w = parse_welcome(&f.body)?;
            if w.m != cfg.m {
                return Err(WireError::Protocol(format!(
                    "server cohort M = {}, client expects {}",
                    w.m, cfg.m
                )));
            }
            if w.dim != dim {
                return Err(WireError::Protocol(format!(
                    "server dim {} != worker dim {dim}",
                    w.dim
                )));
            }
            if let (Some(a), Some(b)) = (w.spec_hash, cfg.spec_hash) {
                if a != b {
                    return Err(WireError::Protocol(format!(
                        "server manifest hash {a:016x} != client {b:016x}"
                    )));
                }
            }
            s.seq_rx = f.seq;
            return Ok(s);
        }
        if Instant::now() > deadline {
            return Err(WireError::Timeout("no Welcome".into()));
        }
    }
}

/// Dial under bounded seeded backoff; `generation` salts the jitter
/// stream so successive reconnects don't thunder in phase.
fn dial_with_backoff(
    worker: &Worker,
    cfg: &ClientConfig,
    generation: u64,
    budget: &mut u32,
) -> Result<Session, WireError> {
    let mut attempt = 0u32;
    loop {
        attempt += 1;
        match dial(worker.id, worker.dim(), cfg) {
            Ok(s) => return Ok(s),
            Err(e @ WireError::Protocol(_)) => return Err(e),
            Err(e @ WireError::Version { .. }) => return Err(e),
            Err(e) => {
                if *budget == 0 {
                    return Err(e);
                }
                *budget -= 1;
                std::thread::sleep(Duration::from_millis(
                    cfg.retry.backoff_ms(
                        worker.id,
                        generation,
                        attempt.saturating_add(1),
                    ),
                ));
            }
        }
    }
}

/// Drive `worker` against a remote server until the server says `Bye`
/// (normal completion) or the reconnect budget runs out.
pub fn run_client(
    worker: &mut Worker,
    censor: Arc<dyn CensorRule>,
    cfg: &ClientConfig,
) -> Result<ClientStats, WireError> {
    let mut stats = ClientStats::default();
    let mut budget = cfg.max_reconnects;
    let mut generation = 0u64;
    // transactional state, carried across reconnects
    let mut pending: Option<(u64, WorkerSnapshot)> = None;
    let mut last_k: u64 = 0;
    let mut cache: Option<Json> = None;
    let heartbeat = Duration::from_millis(cfg.heartbeat_ms.max(1) as u64);
    'redial: loop {
        let mut s = dial_with_backoff(worker, cfg, generation, &mut budget)?;
        if generation > 0 {
            stats.reconnects += 1;
        }
        generation += 1;
        let mut last_heard = Instant::now();
        let mut last_probe = Instant::now();
        loop {
            let frame = match s.reader.poll(&mut s.conn) {
                Ok(Some(f)) => f,
                Ok(None) => {
                    // idle: probe a long-silent server so a dead TCP
                    // stream surfaces as a write error
                    let now = Instant::now();
                    if now.duration_since(last_heard) > heartbeat.mul_f64(3.0)
                        && now.duration_since(last_probe) > heartbeat
                    {
                        last_probe = now;
                        if s.send(
                            FrameKind::Heartbeat,
                            last_k,
                            super::frame::empty_body(),
                        )
                        .is_err()
                        {
                            continue 'redial;
                        }
                    }
                    continue;
                }
                Err(WireError::Crc { .. }) | Err(WireError::Body(_)) => {
                    stats.crc_rejected += 1;
                    continue;
                }
                Err(_) => continue 'redial,
            };
            last_heard = Instant::now();
            if frame.seq <= s.seq_rx {
                stats.dup_suppressed += 1;
                continue;
            }
            s.seq_rx = frame.seq;
            match frame.kind {
                FrameKind::Round => {
                    let msg = match parse_round(&frame.body) {
                        Ok(m) => m,
                        Err(_) => {
                            stats.crc_rejected += 1;
                            continue;
                        }
                    };
                    let k = frame.round;
                    if k < last_k {
                        stats.dup_suppressed += 1;
                        continue;
                    }
                    if k == last_k {
                        // server retry: answer from cache, never
                        // recompute (identical bits, fresh seq)
                        if let Some(body) = &cache {
                            stats.retransmits += 1;
                            let body = body.clone();
                            if s.send(FrameKind::Report, k, body).is_err() {
                                continue 'redial;
                            }
                        }
                        continue;
                    }
                    // a strictly newer round resolves the pending
                    // transactional transmit first
                    if let Some((p, snap)) = pending.take() {
                        if msg.acked >= p {
                            stats.commits += 1;
                        } else {
                            worker.restore(&snap);
                            stats.rollbacks += 1;
                        }
                    }
                    let mut active = vec![false; cfg.m];
                    active[worker.id] = msg.active;
                    let force = if msg.force {
                        let mut f = vec![false; cfg.m];
                        f[worker.id] = true;
                        f
                    } else {
                        Vec::new()
                    };
                    let input = RoundInput {
                        k: k as usize,
                        theta: Arc::new(msg.theta),
                        step_sq: msg.step_sq,
                        active: Arc::new(active),
                        force: Arc::new(force),
                        censor: Arc::clone(&censor),
                    };
                    let snap = worker.snapshot();
                    let r = run_worker_round(worker, &input);
                    stats.rounds += 1;
                    if r.decision == CensorDecision::Transmit {
                        pending = Some((k, snap));
                    }
                    let body = report_body(&r);
                    cache = Some(body.clone());
                    last_k = k;
                    if s.send(FrameKind::Report, k, body).is_err() {
                        continue 'redial;
                    }
                }
                FrameKind::SnapshotReq => {
                    let body = snapshot_body(&worker.snapshot());
                    if s.send(FrameKind::Snapshot, frame.round, body).is_err()
                    {
                        continue 'redial;
                    }
                }
                FrameKind::Restore => {
                    let snap = match parse_snapshot(&frame.body) {
                        Ok(sn) => sn,
                        Err(_) => {
                            stats.crc_rejected += 1;
                            continue;
                        }
                    };
                    if snap.id != worker.id
                        || snap.last_tx.len() != worker.dim()
                    {
                        stats.crc_rejected += 1;
                        continue;
                    }
                    worker.restore(&snap);
                    // restored state is authoritative: whatever was
                    // pending or cached belongs to a dead timeline
                    pending = None;
                    cache = None;
                    last_k = frame.round;
                    if s.send(
                        FrameKind::RestoreAck,
                        frame.round,
                        super::frame::empty_body(),
                    )
                    .is_err()
                    {
                        continue 'redial;
                    }
                }
                FrameKind::Heartbeat => {}
                FrameKind::Bye => {
                    if let Ok(acked) = parse_bye(&frame.body) {
                        if let Some((p, snap)) = pending.take() {
                            if acked >= p {
                                stats.commits += 1;
                            } else {
                                worker.restore(&snap);
                                stats.rollbacks += 1;
                            }
                        }
                    }
                    return Ok(stats);
                }
                _ => {
                    // Welcome/Hello/Report/Snapshot/RestoreAck have no
                    // business arriving here; drop them
                    stats.dup_suppressed += 1;
                }
            }
        }
    }
}
