//! Deterministic PRNG substrate (no `rand` crate on this image).
//!
//! * [`SplitMix64`] — seeding / stream-splitting generator.
//! * [`Xoshiro256`] — xoshiro256++, the workhorse generator used by all
//!   synthetic-data generation and the property-testing driver.
//! * Gaussian sampling via the polar Box–Muller method.
//!
//! Everything here is fully deterministic from a `u64` seed so every
//! experiment in EXPERIMENTS.md is bit-reproducible.

/// SplitMix64 (Steele et al.): used to expand a single `u64` seed into
/// the 256-bit xoshiro state, and to derive independent child seeds.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Generator starting from `seed`.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ 1.0 (Blackman & Vigna).
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 as the authors recommend.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Derive an independent child generator (for per-worker streams).
    pub fn split(&mut self) -> Self {
        Self::new(self.next_u64())
    }

    /// Next 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) via Lemire-style rejection.
    pub fn next_below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "next_below(0)");
        // Rejection sampling on the top bits keeps this unbiased.
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Standard normal via polar Box–Muller (exact, no table).
    pub fn next_gaussian(&mut self) -> f64 {
        loop {
            let u = 2.0 * self.next_f64() - 1.0;
            let v = 2.0 * self.next_f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// ±1 with equal probability (the paper's synthetic labels).
    pub fn next_sign(&mut self) -> f64 {
        if self.next_u64() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Fill a fresh vector with standard normals.
    pub fn gaussian_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.next_gaussian()).collect()
    }

    /// The raw 256-bit generator state (checkpoint capture).
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a captured [`Xoshiro256::state`] —
    /// the restored stream continues bit-identically.
    pub fn from_state(s: [u64; 4]) -> Self {
        Self { s }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_known_values() {
        // Reference values for seed 0 from the public-domain C code.
        let mut sm = SplitMix64::new(0);
        assert_eq!(sm.next_u64(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(sm.next_u64(), 0x6E78_9E6A_A1B9_65F4);
    }

    #[test]
    fn xoshiro_deterministic_and_seed_sensitive() {
        let a: Vec<u64> = {
            let mut r = Xoshiro256::new(42);
            (0..8).map(|_| r.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut r = Xoshiro256::new(42);
            (0..8).map(|_| r.next_u64()).collect()
        };
        let c: Vec<u64> = {
            let mut r = Xoshiro256::new(43);
            (0..8).map(|_| r.next_u64()).collect()
        };
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Xoshiro256::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn next_below_bounds_and_coverage() {
        let mut r = Xoshiro256::new(3);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let v = r.next_below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Xoshiro256::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn signs_are_balanced() {
        let mut r = Xoshiro256::new(5);
        let sum: f64 = (0..100_000).map(|_| r.next_sign()).sum();
        assert!(sum.abs() < 2_000.0, "sum {sum}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::new(9);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
