//! Versioned, self-describing checkpoints with **bit-identical**
//! resume.
//!
//! A [`Checkpoint`] captures everything a run's future depends on —
//! the server iterate pair (θ, θ_prev) and eq. (5) aggregate ∇ᵏ, every
//! worker's censor reference θ̂ (its last-transmitted gradient),
//! error-feedback residuals, the participation and drop RNG streams,
//! the network byte/clock counters, the full trace so far, and (for
//! the asynchronous engine) the pending event queue, per-worker
//! stations, and compute-time streams.  What it deliberately does
//! *not* capture is anything recomputable from the manifest: the
//! update rule (HB/CHB momentum is a pure function of θ − θ_prev),
//! batch-sampler cursors (draws are pure functions of `(worker, seed,
//! k)`), and the fault schedule (a pure function of `(seed, worker,
//! round)`).  Resuming therefore needs the checkpoint **plus** the
//! run's manifest — [`crate::spec::Session::resume`] enforces the
//! pairing through the manifest hash.
//!
//! ## Encoding
//!
//! JSON (via the in-tree [`crate::util::json`] writer), with one
//! deliberate twist: every `f64` is stored as the 16-hex-digit
//! IEEE-754 bit pattern (vectors concatenate, 16 digits per element),
//! and every `u64` likewise.  Decimal shortest-round-trip printing
//! would also be exact, but bit patterns make the bit-identity
//! contract *visible* in the artifact and make corruption detection
//! trivial (length % 16, hex alphabet).  Counters that are small by
//! construction (iteration indices, worker counts) stay plain JSON
//! numbers for readability.
//!
//! Writes are atomic: serialize to `<path>.tmp`, then `rename` over
//! the destination, so a crash mid-write can never leave a torn
//! checkpoint behind — the previous complete one survives.
//!
//! Decoding is strict and total: unknown or missing keys, truncated
//! hex, wrong-arity arrays, and version skew all yield a typed
//! [`CheckpointError`] (never a panic), and a checkpoint value is
//! fully decoded and validated before any engine state is touched, so
//! a corrupt file can never leave a half-mutated run behind.

use std::collections::BTreeMap;
use std::fmt;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::compress::{PackScheme, PackedBuf, Payload};
use crate::coordinator::worker::WorkerRound;
use crate::metrics::{IterStat, StalenessStats, Trace};
use crate::optim::CensorDecision;
use crate::util::json::Json;

/// Format version stamped into every checkpoint file.  Bump on any
/// incompatible layout change; loaders reject mismatches with
/// [`CheckpointError::Version`].
pub const CHECKPOINT_VERSION: u64 = 1;

/// Everything that can go wrong writing, reading, or applying a
/// checkpoint.  Every failure is typed — corruption is an error
/// value, never a panic.
#[derive(Debug)]
pub enum CheckpointError {
    /// filesystem failure (open/read/write/rename)
    Io(std::io::Error),
    /// the file is not syntactically valid JSON
    Parse(String),
    /// the file's format version differs from this build's
    Version {
        /// version stamped in the file
        found: u64,
        /// version this build writes ([`CHECKPOINT_VERSION`])
        expected: u64,
    },
    /// the checkpoint was taken under a different run manifest
    SpecMismatch {
        /// manifest hash stamped in the file
        found: u64,
        /// manifest hash of the resuming session
        expected: u64,
    },
    /// the checkpoint was taken by a different engine kind
    Engine {
        /// engine name stamped in the file
        found: String,
        /// engine the resuming session would run
        expected: String,
    },
    /// the checkpoint's parameter dimension differs from the session's
    Dimension {
        /// dimension stamped in the file
        found: usize,
        /// dimension of the resuming session
        expected: usize,
    },
    /// structurally valid JSON that is not a well-formed checkpoint
    /// (missing/unknown keys, bad hex, internally inconsistent shapes)
    Corrupt(String),
    /// the session's configuration carries state the checkpoint image
    /// does not capture (stateful server rules, compressing downlink),
    /// so checkpointing or resuming it would silently diverge
    Unsupported(String),
}

impl fmt::Display for CheckpointError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CheckpointError::Io(e) => write!(f, "checkpoint I/O: {e}"),
            CheckpointError::Parse(d) => {
                write!(f, "checkpoint is not valid JSON: {d}")
            }
            CheckpointError::Version { found, expected } => write!(
                f,
                "checkpoint format version {found} (this build reads {expected})"
            ),
            CheckpointError::SpecMismatch { found, expected } => write!(
                f,
                "checkpoint belongs to a different manifest \
                 (hash {found:016x}, session manifest {expected:016x})"
            ),
            CheckpointError::Engine { found, expected } => write!(
                f,
                "checkpoint was taken by the {found:?} engine; \
                 session runs {expected:?}"
            ),
            CheckpointError::Dimension { found, expected } => write!(
                f,
                "checkpoint dimension {found} != session dimension {expected}"
            ),
            CheckpointError::Corrupt(d) => {
                write!(f, "corrupt checkpoint: {d}")
            }
            CheckpointError::Unsupported(d) => {
                write!(f, "checkpoint/resume unsupported: {d}")
            }
        }
    }
}

impl std::error::Error for CheckpointError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            CheckpointError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for CheckpointError {
    fn from(e: std::io::Error) -> Self {
        CheckpointError::Io(e)
    }
}

/// FNV-1a 64-bit hash of `text` — stable, dependency-free content
/// address for manifests: checkpoints stamp the manifest they belong
/// to with it, and the artifact store names result directories by it.
pub fn fnv1a64(text: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in text.as_bytes() {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// When and where to write checkpoints.  Environmental (a property of
/// *this execution*, like the artifacts directory), so it lives
/// outside [`crate::spec::RunSpec`] — two runs of one manifest with
/// different checkpoint cadences must stay bit-identical, and do,
/// because writing a checkpoint never draws from any run RNG.
#[derive(Clone, Debug)]
pub struct CheckpointPolicy {
    /// write a checkpoint every `every` server steps (0 = never)
    pub every: usize,
    /// directory the checkpoint file lives in
    pub dir: PathBuf,
}

impl CheckpointPolicy {
    /// Checkpoint every `every` steps into `dir`.
    pub fn new(every: usize, dir: impl Into<PathBuf>) -> Self {
        Self { every, dir: dir.into() }
    }

    /// The checkpoint file path (a single file, atomically replaced).
    pub fn path(&self) -> PathBuf {
        self.dir.join("checkpoint.json")
    }

    /// Is a checkpoint due after server step `k`?
    pub fn due(&self, k: usize) -> bool {
        self.every > 0 && k % self.every == 0
    }
}

/// Server-side state: the iterate pair, the eq. (5) aggregate, and
/// the step counter.  The update rule itself is rebuilt from the
/// manifest (momentum is a pure function of θ − θ_prev).
#[derive(Clone, Debug, PartialEq)]
pub struct ServerState {
    /// current iterate θᵏ
    pub theta: Vec<f64>,
    /// previous iterate θ^{k−1}
    pub theta_prev: Vec<f64>,
    /// running aggregate ∇ᵏ
    pub agg_grad: Vec<f64>,
    /// server steps taken
    pub k: usize,
}

/// One worker's censor-relevant state: its reference θ̂ (the
/// last-transmitted gradient), lifetime transmission count, and the
/// error-feedback residual (empty when no EF compressor is attached).
#[derive(Clone, Debug, PartialEq)]
pub struct WorkerState {
    /// worker id (0-based, dense)
    pub id: usize,
    /// last-transmitted gradient ∇f_m(θ̂_m)
    pub last_tx: Vec<f64>,
    /// lifetime uplink transmissions S_m
    pub transmissions: usize,
    /// error-feedback residual carried by the codec scratch
    pub residual: Vec<f64>,
}

/// One link's delivered-message counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LinkState {
    /// messages delivered
    pub messages: u64,
    /// payload bytes delivered
    pub bytes: u64,
}

/// The simulated network's full state: drop-stream RNG, counters, and
/// per-link accounting in both directions.
#[derive(Clone, Debug, PartialEq)]
pub struct NetState {
    /// drop-stream RNG (Xoshiro256** raw state)
    pub rng: [u64; 4],
    /// uplink messages lost to failure injection so far
    pub dropped: u64,
    /// accumulated simulated wallclock (µs)
    pub sim_clock_us: f64,
    /// per-worker uplink counters
    pub up: Vec<LinkState>,
    /// per-worker downlink counters
    pub down: Vec<LinkState>,
}

/// What a worker is computing against in the async engine (the θ
/// snapshot frozen when the server issued its broadcast).
#[derive(Clone, Debug, PartialEq)]
pub struct StationState {
    /// the broadcast iterate
    pub theta: Vec<f64>,
    /// ‖θ − θ_prev‖² at broadcast time
    pub step_sq: f64,
    /// server step count when the broadcast was issued
    pub version: usize,
}

/// Serializable form of one pending async event's payload.
#[derive(Clone, Debug)]
pub enum EvSnap {
    /// θ broadcast in flight toward a worker
    Down,
    /// a worker's gradient round in progress
    Compute,
    /// a worker report in flight toward the server
    Up {
        /// the full report (decision, payload, loss, …)
        round: WorkerRound,
        /// server step count its θ was issued at
        version: usize,
    },
}

/// One pending event with its exact queue key, so a restored queue
/// pops in exactly the order the original would have.
#[derive(Clone, Debug)]
pub struct QueuedEv {
    /// virtual delivery time (µs)
    pub time_us: f64,
    /// same-instant phase rank
    pub rank: u8,
    /// worker the event concerns
    pub worker: usize,
    /// push-order tiebreaker
    pub seq: u64,
    /// the event payload
    pub ev: EvSnap,
}

/// The asynchronous engine's extra state: the event queue, per-worker
/// stations, compute-time streams, loss cache, staleness-censor
/// counters, and the telescoping bookkeeping sums.
#[derive(Clone, Debug)]
pub struct AsyncState {
    /// pending events, sorted by the queue's total order
    pub queue: Vec<QueuedEv>,
    /// the queue's next push sequence number
    pub seq: u64,
    /// the queue's last popped virtual time (µs)
    pub last_popped_us: f64,
    /// per-worker broadcast snapshots
    pub stations: Vec<StationState>,
    /// latest known per-worker loss (global-loss instrumentation)
    pub loss_cache: Vec<f64>,
    /// per-worker compute-time RNG streams (Xoshiro256** raw state)
    pub comp_rng: Vec<[u64; 4]>,
    /// per-worker consecutive-skip counters of the staleness-bounded
    /// censor wrappers (empty when no staleness bound is configured)
    pub censor_skips: Vec<usize>,
    /// per-worker completed local gradient rounds (the fault plan's
    /// per-worker round key in the async regime)
    pub local_rounds: Vec<usize>,
    /// Σ folded deltas (telescope bookkeeping)
    pub applied_sum: Vec<f64>,
    /// Σ transmitted deltas lost to drops
    pub dropped_sum: Vec<f64>,
    /// virtual clock at capture (µs)
    pub vclock_us: f64,
}

/// A complete, self-describing snapshot of a run at server step `k`.
#[derive(Clone, Debug)]
pub struct Checkpoint {
    /// format version ([`CHECKPOINT_VERSION`])
    pub version: u64,
    /// FNV-1a hash of the run's `manifest.json` text, when the run
    /// came from a [`crate::spec::Session`] (None for raw engine runs)
    pub spec_hash: Option<u64>,
    /// engine kind name ("serial", "threaded", "rayon", "async")
    pub engine: String,
    /// server step the snapshot was taken after
    pub k: usize,
    /// parameter dimension d
    pub dim: usize,
    /// server state
    pub server: ServerState,
    /// per-worker state, ordered by id
    pub workers: Vec<WorkerState>,
    /// participation-schedule RNG (None for the async engine, which
    /// is full-participation by construction)
    pub schedule_rng: Option<[u64; 4]>,
    /// network counters and drop stream
    pub net: NetState,
    /// the trace accumulated so far (resume appends to it)
    pub trace: Trace,
    /// async-engine state (None for the synchronous engines)
    pub async_state: Option<AsyncState>,
}

impl Checkpoint {
    /// Number of workers M.
    pub fn num_workers(&self) -> usize {
        self.workers.len()
    }

    /// Validate this checkpoint against a resuming session's
    /// identity.  `spec_hash` is compared only when both sides carry
    /// one, so raw engine runs interoperate.
    pub fn check_compat(
        &self,
        spec_hash: Option<u64>,
        engine: &str,
        dim: usize,
        m: usize,
    ) -> Result<(), CheckpointError> {
        if let (Some(found), Some(expected)) = (self.spec_hash, spec_hash) {
            if found != expected {
                return Err(CheckpointError::SpecMismatch { found, expected });
            }
        }
        if self.engine != engine {
            return Err(CheckpointError::Engine {
                found: self.engine.clone(),
                expected: engine.to_string(),
            });
        }
        if self.dim != dim {
            return Err(CheckpointError::Dimension {
                found: self.dim,
                expected: dim,
            });
        }
        if self.workers.len() != m {
            return Err(CheckpointError::Corrupt(format!(
                "checkpoint has {} workers, session has {m}",
                self.workers.len()
            )));
        }
        Ok(())
    }

    /// Serialize to the canonical pretty JSON text (trailing newline).
    pub fn to_json_string(&self) -> String {
        let mut s = self.to_json().dump_pretty();
        s.push('\n');
        s
    }

    /// Parse and fully validate checkpoint text.
    pub fn from_json_str(text: &str) -> Result<Checkpoint, CheckpointError> {
        let v = Json::parse(text)
            .map_err(|e| CheckpointError::Parse(e.to_string()))?;
        Self::from_json(&v)
    }

    /// Atomically write to `path`: serialize to `<path>.tmp`, then
    /// rename over the destination, so a crash mid-write leaves the
    /// previous complete checkpoint intact.
    pub fn save(&self, path: &Path) -> Result<(), CheckpointError> {
        atomic_write(path, &self.to_json_string())?;
        Ok(())
    }

    /// Load and fully validate a checkpoint file.
    pub fn load(path: &Path) -> Result<Checkpoint, CheckpointError> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json_str(&text)
    }

    fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("version".into(), Json::Num(self.version as f64));
        if let Some(h) = self.spec_hash {
            o.insert("spec_hash".into(), Json::Str(hex_u64(h)));
        }
        o.insert("engine".into(), Json::Str(self.engine.clone()));
        o.insert("k".into(), Json::Num(self.k as f64));
        o.insert("dim".into(), Json::Num(self.dim as f64));
        o.insert("server".into(), {
            let mut s = BTreeMap::new();
            s.insert("theta".into(), hex_f64_vec(&self.server.theta));
            s.insert("theta_prev".into(), hex_f64_vec(&self.server.theta_prev));
            s.insert("agg_grad".into(), hex_f64_vec(&self.server.agg_grad));
            s.insert("k".into(), Json::Num(self.server.k as f64));
            Json::Obj(s)
        });
        o.insert(
            "workers".into(),
            Json::Arr(
                self.workers
                    .iter()
                    .map(|w| {
                        let mut m = BTreeMap::new();
                        m.insert("id".into(), Json::Num(w.id as f64));
                        m.insert("last_tx".into(), hex_f64_vec(&w.last_tx));
                        m.insert(
                            "transmissions".into(),
                            Json::Num(w.transmissions as f64),
                        );
                        m.insert("residual".into(), hex_f64_vec(&w.residual));
                        Json::Obj(m)
                    })
                    .collect(),
            ),
        );
        o.insert(
            "schedule_rng".into(),
            match &self.schedule_rng {
                Some(s) => rng_to_json(s),
                None => Json::Null,
            },
        );
        o.insert("net".into(), {
            let mut n = BTreeMap::new();
            n.insert("rng".into(), rng_to_json(&self.net.rng));
            n.insert("dropped".into(), Json::Str(hex_u64(self.net.dropped)));
            n.insert(
                "sim_clock_us".into(),
                Json::Str(hex_f64(self.net.sim_clock_us)),
            );
            n.insert("up".into(), links_to_json(&self.net.up));
            n.insert("down".into(), links_to_json(&self.net.down));
            Json::Obj(n)
        });
        o.insert("trace".into(), trace_to_json(&self.trace));
        if let Some(a) = &self.async_state {
            o.insert("async".into(), async_to_json(a));
        }
        Json::Obj(o)
    }

    fn from_json(v: &Json) -> Result<Checkpoint, CheckpointError> {
        let o = as_obj(v, "checkpoint")?;
        // version gate first: a bumped version changes layout freely,
        // so nothing else is decoded before this check
        let version = num_field(o, "version", "checkpoint")?;
        if version != CHECKPOINT_VERSION {
            return Err(CheckpointError::Version {
                found: version,
                expected: CHECKPOINT_VERSION,
            });
        }
        check_keys(
            o,
            &[
                "version", "engine", "k", "dim", "server", "workers",
                "schedule_rng", "net", "trace",
            ],
            &["spec_hash", "async"],
            "checkpoint",
        )?;
        let spec_hash = match o.get("spec_hash") {
            None => None,
            Some(j) => Some(u64_from_json(j, "spec_hash")?),
        };
        let engine = str_field(o, "engine", "checkpoint")?.to_string();
        let k = num_field(o, "k", "checkpoint")? as usize;
        let dim = num_field(o, "dim", "checkpoint")? as usize;

        let so = as_obj(req(o, "server", "checkpoint")?, "server")?;
        check_keys(so, &["theta", "theta_prev", "agg_grad", "k"], &[], "server")?;
        let server = ServerState {
            theta: f64_vec_field(so, "theta", "server")?,
            theta_prev: f64_vec_field(so, "theta_prev", "server")?,
            agg_grad: f64_vec_field(so, "agg_grad", "server")?,
            k: num_field(so, "k", "server")? as usize,
        };
        for (name, v) in [
            ("theta", &server.theta),
            ("theta_prev", &server.theta_prev),
            ("agg_grad", &server.agg_grad),
        ] {
            if v.len() != dim {
                return Err(CheckpointError::Corrupt(format!(
                    "server.{name} has {} elements, dim is {dim}",
                    v.len()
                )));
            }
        }
        if server.k != k {
            return Err(CheckpointError::Corrupt(format!(
                "server.k {} != checkpoint k {k}",
                server.k
            )));
        }

        let warr = arr_field(o, "workers", "checkpoint")?;
        let mut workers = Vec::with_capacity(warr.len());
        for (i, wj) in warr.iter().enumerate() {
            let wo = as_obj(wj, "worker")?;
            check_keys(
                wo,
                &["id", "last_tx", "transmissions", "residual"],
                &[],
                "worker",
            )?;
            let w = WorkerState {
                id: num_field(wo, "id", "worker")? as usize,
                last_tx: f64_vec_field(wo, "last_tx", "worker")?,
                transmissions: num_field(wo, "transmissions", "worker")?
                    as usize,
                residual: f64_vec_field(wo, "residual", "worker")?,
            };
            if w.id != i {
                return Err(CheckpointError::Corrupt(format!(
                    "worker {i} carries id {}",
                    w.id
                )));
            }
            if w.last_tx.len() != dim {
                return Err(CheckpointError::Corrupt(format!(
                    "worker {i} last_tx has {} elements, dim is {dim}",
                    w.last_tx.len()
                )));
            }
            if !w.residual.is_empty() && w.residual.len() != dim {
                return Err(CheckpointError::Corrupt(format!(
                    "worker {i} residual has {} elements, dim is {dim}",
                    w.residual.len()
                )));
            }
            workers.push(w);
        }

        let schedule_rng = match req(o, "schedule_rng", "checkpoint")? {
            Json::Null => None,
            j => Some(rng_from_json(j, "schedule_rng")?),
        };

        let no = as_obj(req(o, "net", "checkpoint")?, "net")?;
        check_keys(
            no,
            &["rng", "dropped", "sim_clock_us", "up", "down"],
            &[],
            "net",
        )?;
        let net = NetState {
            rng: rng_from_json(req(no, "rng", "net")?, "net.rng")?,
            dropped: u64_from_json(req(no, "dropped", "net")?, "net.dropped")?,
            sim_clock_us: f64_from_json(
                req(no, "sim_clock_us", "net")?,
                "net.sim_clock_us",
            )?,
            up: links_from_json(req(no, "up", "net")?, "net.up")?,
            down: links_from_json(req(no, "down", "net")?, "net.down")?,
        };
        if net.up.len() != workers.len() || net.down.len() != workers.len() {
            return Err(CheckpointError::Corrupt(format!(
                "net has {}/{} links for {} workers",
                net.up.len(),
                net.down.len(),
                workers.len()
            )));
        }

        let trace = trace_from_json(req(o, "trace", "checkpoint")?)?;
        let async_state = match o.get("async") {
            None => None,
            Some(j) => Some(async_from_json(j, dim, workers.len())?),
        };
        Ok(Checkpoint {
            version,
            spec_hash,
            engine,
            k,
            dim,
            server,
            workers,
            schedule_rng,
            net,
            trace,
            async_state,
        })
    }
}

// ---------------------------------------------------------------------------
// atomic file writes — the tmp + rename path every durable artifact uses
// ---------------------------------------------------------------------------

/// Write `text` to `path` atomically: the content lands in `<path>.tmp`
/// first and is renamed over the destination, so a process killed
/// mid-write can never leave a torn file — readers see either the
/// previous complete content or the new complete content.  Parent
/// directories are created as needed.  This is the one write path every
/// durable artifact (checkpoints, `manifest.json`, trace CSVs, the
/// results-store index) goes through.
pub fn atomic_write(path: &Path, text: &str) -> std::io::Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    let mut tmp = path.as_os_str().to_owned();
    tmp.push(".tmp");
    let tmp = PathBuf::from(tmp);
    std::fs::write(&tmp, text)?;
    std::fs::rename(&tmp, path)?;
    Ok(())
}

// ---------------------------------------------------------------------------
// hex codecs — every f64/u64 is a 16-hex-digit bit pattern
// ---------------------------------------------------------------------------

pub(crate) fn hex_f64(x: f64) -> String {
    format!("{:016x}", x.to_bits())
}

pub(crate) fn hex_u64(x: u64) -> String {
    format!("{x:016x}")
}

pub(crate) fn hex_f64_vec(v: &[f64]) -> Json {
    let mut s = String::with_capacity(v.len() * 16);
    for x in v {
        s.push_str(&hex_f64(*x));
    }
    Json::Str(s)
}

fn hex_u64_vec(v: &[u64]) -> Json {
    let mut s = String::with_capacity(v.len() * 16);
    for x in v {
        s.push_str(&hex_u64(*x));
    }
    Json::Str(s)
}

pub(crate) fn u64_from_hex(s: &str, what: &str) -> Result<u64, CheckpointError> {
    if s.len() != 16 || !s.bytes().all(|b| b.is_ascii_hexdigit()) {
        return Err(CheckpointError::Corrupt(format!(
            "{what}: {s:?} is not a 16-hex-digit word"
        )));
    }
    u64::from_str_radix(s, 16)
        .map_err(|e| CheckpointError::Corrupt(format!("{what}: {e}")))
}

fn u64_vec_from_hex(s: &str, what: &str) -> Result<Vec<u64>, CheckpointError> {
    if s.len() % 16 != 0 {
        return Err(CheckpointError::Corrupt(format!(
            "{what}: hex length {} is not a multiple of 16",
            s.len()
        )));
    }
    let mut out = Vec::with_capacity(s.len() / 16);
    for i in (0..s.len()).step_by(16) {
        out.push(u64_from_hex(&s[i..i + 16], what)?);
    }
    Ok(out)
}

pub(crate) fn f64_vec_from_hex(
    s: &str,
    what: &str,
) -> Result<Vec<f64>, CheckpointError> {
    Ok(u64_vec_from_hex(s, what)?
        .into_iter()
        .map(f64::from_bits)
        .collect())
}

// ---------------------------------------------------------------------------
// strict JSON accessors
// ---------------------------------------------------------------------------

pub(crate) fn as_obj<'a>(
    v: &'a Json,
    what: &str,
) -> Result<&'a BTreeMap<String, Json>, CheckpointError> {
    match v {
        Json::Obj(m) => Ok(m),
        _ => Err(CheckpointError::Corrupt(format!("{what} is not an object"))),
    }
}

pub(crate) fn req<'a>(
    o: &'a BTreeMap<String, Json>,
    key: &str,
    what: &str,
) -> Result<&'a Json, CheckpointError> {
    o.get(key).ok_or_else(|| {
        CheckpointError::Corrupt(format!("{what} is missing key {key:?}"))
    })
}

pub(crate) fn check_keys(
    o: &BTreeMap<String, Json>,
    required: &[&str],
    optional: &[&str],
    what: &str,
) -> Result<(), CheckpointError> {
    for key in required {
        if !o.contains_key(*key) {
            return Err(CheckpointError::Corrupt(format!(
                "{what} is missing key {key:?}"
            )));
        }
    }
    for key in o.keys() {
        if !required.contains(&key.as_str())
            && !optional.contains(&key.as_str())
        {
            return Err(CheckpointError::Corrupt(format!(
                "{what} has unknown key {key:?}"
            )));
        }
    }
    Ok(())
}

pub(crate) fn num_field(
    o: &BTreeMap<String, Json>,
    key: &str,
    what: &str,
) -> Result<u64, CheckpointError> {
    match req(o, key, what)? {
        Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n < 9.1e15 => {
            Ok(*n as u64)
        }
        other => Err(CheckpointError::Corrupt(format!(
            "{what}.{key} is not a non-negative integer (got {other:?})"
        ))),
    }
}

pub(crate) fn str_field<'a>(
    o: &'a BTreeMap<String, Json>,
    key: &str,
    what: &str,
) -> Result<&'a str, CheckpointError> {
    req(o, key, what)?.as_str().ok_or_else(|| {
        CheckpointError::Corrupt(format!("{what}.{key} is not a string"))
    })
}

fn arr_field<'a>(
    o: &'a BTreeMap<String, Json>,
    key: &str,
    what: &str,
) -> Result<&'a [Json], CheckpointError> {
    req(o, key, what)?.as_arr().ok_or_else(|| {
        CheckpointError::Corrupt(format!("{what}.{key} is not an array"))
    })
}

pub(crate) fn f64_from_json(
    v: &Json,
    what: &str,
) -> Result<f64, CheckpointError> {
    match v {
        Json::Str(s) => Ok(f64::from_bits(u64_from_hex(s, what)?)),
        _ => Err(CheckpointError::Corrupt(format!(
            "{what} is not a hex-f64 string"
        ))),
    }
}

pub(crate) fn u64_from_json(
    v: &Json,
    what: &str,
) -> Result<u64, CheckpointError> {
    match v {
        Json::Str(s) => u64_from_hex(s, what),
        _ => Err(CheckpointError::Corrupt(format!(
            "{what} is not a hex-u64 string"
        ))),
    }
}

pub(crate) fn f64_vec_field(
    o: &BTreeMap<String, Json>,
    key: &str,
    what: &str,
) -> Result<Vec<f64>, CheckpointError> {
    match req(o, key, what)? {
        Json::Str(s) => f64_vec_from_hex(s, &format!("{what}.{key}")),
        _ => Err(CheckpointError::Corrupt(format!(
            "{what}.{key} is not a hex-vector string"
        ))),
    }
}

fn usize_arr(v: &Json, what: &str) -> Result<Vec<usize>, CheckpointError> {
    let arr = v.as_arr().ok_or_else(|| {
        CheckpointError::Corrupt(format!("{what} is not an array"))
    })?;
    arr.iter()
        .map(|j| match j {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n < 9.1e15 => {
                Ok(*n as usize)
            }
            other => Err(CheckpointError::Corrupt(format!(
                "{what} element is not a non-negative integer (got {other:?})"
            ))),
        })
        .collect()
}

fn usize_arr_json(v: &[usize]) -> Json {
    Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
}

fn rng_to_json(s: &[u64; 4]) -> Json {
    Json::Arr(s.iter().map(|&w| Json::Str(hex_u64(w))).collect())
}

fn rng_from_json(v: &Json, what: &str) -> Result<[u64; 4], CheckpointError> {
    let arr = v.as_arr().ok_or_else(|| {
        CheckpointError::Corrupt(format!("{what} is not an array"))
    })?;
    if arr.len() != 4 {
        return Err(CheckpointError::Corrupt(format!(
            "{what} has {} words, expected 4",
            arr.len()
        )));
    }
    let mut out = [0u64; 4];
    for (i, j) in arr.iter().enumerate() {
        out[i] = u64_from_json(j, what)?;
    }
    Ok(out)
}

fn links_to_json(links: &[LinkState]) -> Json {
    // two parallel hex vectors — compact, strict, and shape-checkable
    let mut o = BTreeMap::new();
    o.insert(
        "messages".into(),
        hex_u64_vec(&links.iter().map(|l| l.messages).collect::<Vec<_>>()),
    );
    o.insert(
        "bytes".into(),
        hex_u64_vec(&links.iter().map(|l| l.bytes).collect::<Vec<_>>()),
    );
    Json::Obj(o)
}

fn links_from_json(
    v: &Json,
    what: &str,
) -> Result<Vec<LinkState>, CheckpointError> {
    let o = as_obj(v, what)?;
    check_keys(o, &["messages", "bytes"], &[], what)?;
    let messages = match req(o, "messages", what)? {
        Json::Str(s) => u64_vec_from_hex(s, what)?,
        _ => {
            return Err(CheckpointError::Corrupt(format!(
                "{what}.messages is not a hex-vector string"
            )))
        }
    };
    let bytes = match req(o, "bytes", what)? {
        Json::Str(s) => u64_vec_from_hex(s, what)?,
        _ => {
            return Err(CheckpointError::Corrupt(format!(
                "{what}.bytes is not a hex-vector string"
            )))
        }
    };
    if messages.len() != bytes.len() {
        return Err(CheckpointError::Corrupt(format!(
            "{what}: {} message counters vs {} byte counters",
            messages.len(),
            bytes.len()
        )));
    }
    Ok(messages
        .into_iter()
        .zip(bytes)
        .map(|(messages, bytes)| LinkState { messages, bytes })
        .collect())
}

// ---------------------------------------------------------------------------
// Trace codec (columnar iters, bitmap comm rows)
// ---------------------------------------------------------------------------

fn trace_to_json(t: &Trace) -> Json {
    let mut o = BTreeMap::new();
    o.insert("method".into(), Json::Str(t.method.clone()));
    let mut it = BTreeMap::new();
    it.insert(
        "k".into(),
        usize_arr_json(&t.iters.iter().map(|s| s.k).collect::<Vec<_>>()),
    );
    it.insert(
        "loss".into(),
        hex_f64_vec(&t.iters.iter().map(|s| s.loss).collect::<Vec<_>>()),
    );
    it.insert(
        "comms_round".into(),
        usize_arr_json(
            &t.iters.iter().map(|s| s.comms_round).collect::<Vec<_>>(),
        ),
    );
    it.insert(
        "comms_cum".into(),
        usize_arr_json(&t.iters.iter().map(|s| s.comms_cum).collect::<Vec<_>>()),
    );
    it.insert(
        "agg_grad_sq".into(),
        hex_f64_vec(&t.iters.iter().map(|s| s.agg_grad_sq).collect::<Vec<_>>()),
    );
    it.insert(
        "step_sq".into(),
        hex_f64_vec(&t.iters.iter().map(|s| s.step_sq).collect::<Vec<_>>()),
    );
    it.insert(
        "bits_cum".into(),
        hex_u64_vec(&t.iters.iter().map(|s| s.bits_cum).collect::<Vec<_>>()),
    );
    it.insert(
        "down_bits_cum".into(),
        hex_u64_vec(
            &t.iters.iter().map(|s| s.down_bits_cum).collect::<Vec<_>>(),
        ),
    );
    it.insert(
        "vclock_us".into(),
        hex_f64_vec(&t.iters.iter().map(|s| s.vclock_us).collect::<Vec<_>>()),
    );
    it.insert(
        "stale_max".into(),
        usize_arr_json(&t.iters.iter().map(|s| s.stale_max).collect::<Vec<_>>()),
    );
    it.insert(
        "batch_frac".into(),
        hex_f64_vec(&t.iters.iter().map(|s| s.batch_frac).collect::<Vec<_>>()),
    );
    it.insert(
        "epoch".into(),
        hex_f64_vec(&t.iters.iter().map(|s| s.epoch).collect::<Vec<_>>()),
    );
    o.insert("iters".into(), Json::Obj(it));
    o.insert("per_worker_comms".into(), usize_arr_json(&t.per_worker_comms));
    o.insert("participants".into(), usize_arr_json(&t.participants));
    o.insert(
        "comm_map".into(),
        Json::Arr(
            t.comm_map
                .iter()
                .map(|row| {
                    Json::Str(
                        row.iter().map(|&b| if b { '1' } else { '0' }).collect(),
                    )
                })
                .collect(),
        ),
    );
    let mut st = BTreeMap::new();
    st.insert(
        "folds".into(),
        usize_arr_json(
            &t.worker_staleness.iter().map(|s| s.folds).collect::<Vec<_>>(),
        ),
    );
    st.insert(
        "max".into(),
        usize_arr_json(
            &t.worker_staleness.iter().map(|s| s.max).collect::<Vec<_>>(),
        ),
    );
    st.insert(
        "sum".into(),
        usize_arr_json(
            &t.worker_staleness.iter().map(|s| s.sum).collect::<Vec<_>>(),
        ),
    );
    o.insert("worker_staleness".into(), Json::Obj(st));
    o.insert("fault_downs".into(), Json::Num(t.fault_downs as f64));
    o.insert("fault_rejoins".into(), Json::Num(t.fault_rejoins as f64));
    Json::Obj(o)
}

fn trace_from_json(v: &Json) -> Result<Trace, CheckpointError> {
    let o = as_obj(v, "trace")?;
    check_keys(
        o,
        &[
            "method", "iters", "per_worker_comms", "participants", "comm_map",
            "worker_staleness", "fault_downs", "fault_rejoins",
        ],
        &[],
        "trace",
    )?;
    let it = as_obj(req(o, "iters", "trace")?, "trace.iters")?;
    check_keys(
        it,
        &[
            "k", "loss", "comms_round", "comms_cum", "agg_grad_sq", "step_sq",
            "bits_cum", "vclock_us", "stale_max", "batch_frac", "epoch",
        ],
        // added after PR 7's format froze; absent in older images,
        // decoded as zeros (pre-downlink runs charged no broadcast)
        &["down_bits_cum"],
        "trace.iters",
    )?;
    let ks = usize_arr(req(it, "k", "trace.iters")?, "trace.iters.k")?;
    let loss = f64_vec_field(it, "loss", "trace.iters")?;
    let comms_round =
        usize_arr(req(it, "comms_round", "trace.iters")?, "comms_round")?;
    let comms_cum =
        usize_arr(req(it, "comms_cum", "trace.iters")?, "comms_cum")?;
    let agg_grad_sq = f64_vec_field(it, "agg_grad_sq", "trace.iters")?;
    let step_sq = f64_vec_field(it, "step_sq", "trace.iters")?;
    let bits_cum = match req(it, "bits_cum", "trace.iters")? {
        Json::Str(s) => u64_vec_from_hex(s, "trace.iters.bits_cum")?,
        _ => {
            return Err(CheckpointError::Corrupt(
                "trace.iters.bits_cum is not a hex-vector string".into(),
            ))
        }
    };
    let down_bits_cum = match it.get("down_bits_cum") {
        Some(Json::Str(s)) => u64_vec_from_hex(s, "trace.iters.down_bits_cum")?,
        Some(_) => {
            return Err(CheckpointError::Corrupt(
                "trace.iters.down_bits_cum is not a hex-vector string".into(),
            ))
        }
        None => vec![0; ks.len()],
    };
    let vclock_us = f64_vec_field(it, "vclock_us", "trace.iters")?;
    let stale_max = usize_arr(req(it, "stale_max", "trace.iters")?, "stale_max")?;
    let batch_frac = f64_vec_field(it, "batch_frac", "trace.iters")?;
    let epoch = f64_vec_field(it, "epoch", "trace.iters")?;
    let n = ks.len();
    for (name, len) in [
        ("loss", loss.len()),
        ("comms_round", comms_round.len()),
        ("comms_cum", comms_cum.len()),
        ("agg_grad_sq", agg_grad_sq.len()),
        ("step_sq", step_sq.len()),
        ("bits_cum", bits_cum.len()),
        ("down_bits_cum", down_bits_cum.len()),
        ("vclock_us", vclock_us.len()),
        ("stale_max", stale_max.len()),
        ("batch_frac", batch_frac.len()),
        ("epoch", epoch.len()),
    ] {
        if len != n {
            return Err(CheckpointError::Corrupt(format!(
                "trace.iters.{name} has {len} rows, k has {n}"
            )));
        }
    }
    let iters = (0..n)
        .map(|i| IterStat {
            k: ks[i],
            loss: loss[i],
            comms_round: comms_round[i],
            comms_cum: comms_cum[i],
            agg_grad_sq: agg_grad_sq[i],
            step_sq: step_sq[i],
            bits_cum: bits_cum[i],
            down_bits_cum: down_bits_cum[i],
            vclock_us: vclock_us[i],
            stale_max: stale_max[i],
            batch_frac: batch_frac[i],
            epoch: epoch[i],
        })
        .collect();
    let comm_map = arr_field(o, "comm_map", "trace")?
        .iter()
        .map(|row| {
            let s = row.as_str().ok_or_else(|| {
                CheckpointError::Corrupt(
                    "trace.comm_map row is not a string".into(),
                )
            })?;
            s.chars()
                .map(|c| match c {
                    '0' => Ok(false),
                    '1' => Ok(true),
                    other => Err(CheckpointError::Corrupt(format!(
                        "trace.comm_map row has non-bit char {other:?}"
                    ))),
                })
                .collect::<Result<Vec<bool>, _>>()
        })
        .collect::<Result<Vec<_>, _>>()?;
    let sto = as_obj(req(o, "worker_staleness", "trace")?, "worker_staleness")?;
    check_keys(sto, &["folds", "max", "sum"], &[], "trace.worker_staleness")?;
    let folds = usize_arr(req(sto, "folds", "worker_staleness")?, "folds")?;
    let maxs = usize_arr(req(sto, "max", "worker_staleness")?, "max")?;
    let sums = usize_arr(req(sto, "sum", "worker_staleness")?, "sum")?;
    if folds.len() != maxs.len() || folds.len() != sums.len() {
        return Err(CheckpointError::Corrupt(
            "trace.worker_staleness columns disagree in length".into(),
        ));
    }
    let worker_staleness = (0..folds.len())
        .map(|i| StalenessStats { folds: folds[i], max: maxs[i], sum: sums[i] })
        .collect();
    Ok(Trace {
        method: str_field(o, "method", "trace")?.to_string(),
        iters,
        per_worker_comms: usize_arr(
            req(o, "per_worker_comms", "trace")?,
            "per_worker_comms",
        )?,
        participants: usize_arr(
            req(o, "participants", "trace")?,
            "participants",
        )?,
        comm_map,
        worker_staleness,
        fault_downs: num_field(o, "fault_downs", "trace")? as usize,
        fault_rejoins: num_field(o, "fault_rejoins", "trace")? as usize,
    })
}

// ---------------------------------------------------------------------------
// Payload / WorkerRound / async-state codecs
// ---------------------------------------------------------------------------

fn payload_to_json(p: &Payload) -> Json {
    let mut o = BTreeMap::new();
    match p {
        Payload::Dense(v) => {
            o.insert("kind".into(), Json::Str("dense".into()));
            o.insert("data".into(), hex_f64_vec(v));
        }
        Payload::Sparse { idx, val } => {
            o.insert("kind".into(), Json::Str("sparse".into()));
            o.insert(
                "idx".into(),
                usize_arr_json(
                    &idx.iter().map(|&i| i as usize).collect::<Vec<_>>(),
                ),
            );
            o.insert("val".into(), hex_f64_vec(val));
        }
        Payload::Packed(buf) => {
            o.insert("kind".into(), Json::Str("packed".into()));
            o.insert(
                "scheme".into(),
                Json::Str(match buf.scheme {
                    PackScheme::Fp32 => "fp32".to_string(),
                    PackScheme::Fp16 => "fp16".to_string(),
                    PackScheme::Int { bits } => format!("int:{bits}"),
                }),
            );
            o.insert("len".into(), Json::Num(f64::from(buf.len)));
            o.insert("scale".into(), Json::Str(hex_f64(buf.scale)));
            o.insert("words".into(), hex_u64_vec(&buf.words));
        }
    }
    Json::Obj(o)
}

fn payload_from_json(v: &Json) -> Result<Payload, CheckpointError> {
    let o = as_obj(v, "payload")?;
    match str_field(o, "kind", "payload")? {
        "dense" => {
            check_keys(o, &["kind", "data"], &[], "payload")?;
            Ok(Payload::Dense(f64_vec_field(o, "data", "payload")?))
        }
        "sparse" => {
            check_keys(o, &["kind", "idx", "val"], &[], "payload")?;
            let idx = usize_arr(req(o, "idx", "payload")?, "payload.idx")?
                .into_iter()
                .map(|i| {
                    u32::try_from(i).map_err(|_| {
                        CheckpointError::Corrupt(format!(
                            "payload.idx {i} exceeds u32"
                        ))
                    })
                })
                .collect::<Result<Vec<_>, _>>()?;
            let val = f64_vec_field(o, "val", "payload")?;
            if idx.len() != val.len() {
                return Err(CheckpointError::Corrupt(format!(
                    "sparse payload has {} indices and {} values",
                    idx.len(),
                    val.len()
                )));
            }
            Ok(Payload::Sparse { idx, val })
        }
        "packed" => {
            check_keys(
                o,
                &["kind", "scheme", "len", "scale", "words"],
                &[],
                "payload",
            )?;
            let scheme = match str_field(o, "scheme", "payload")? {
                "fp32" => PackScheme::Fp32,
                "fp16" => PackScheme::Fp16,
                s if s.starts_with("int:") => {
                    let bits = s["int:".len()..].parse::<u32>().map_err(
                        |e| {
                            CheckpointError::Corrupt(format!(
                                "packed scheme {s:?}: {e}"
                            ))
                        },
                    )?;
                    PackScheme::Int { bits }
                }
                other => {
                    return Err(CheckpointError::Corrupt(format!(
                        "unknown pack scheme {other:?}"
                    )))
                }
            };
            let len = num_field(o, "len", "payload")? as u32;
            let scale = f64_from_json(req(o, "scale", "payload")?, "scale")?;
            let words = match req(o, "words", "payload")? {
                Json::Str(s) => u64_vec_from_hex(s, "payload.words")?,
                _ => {
                    return Err(CheckpointError::Corrupt(
                        "payload.words is not a hex-vector string".into(),
                    ))
                }
            };
            Ok(Payload::Packed(PackedBuf { scheme, len, scale, words }))
        }
        other => Err(CheckpointError::Corrupt(format!(
            "unknown payload kind {other:?}"
        ))),
    }
}

pub(crate) fn round_to_json(r: &WorkerRound) -> Json {
    let mut o = BTreeMap::new();
    o.insert("worker".into(), Json::Num(r.worker as f64));
    o.insert(
        "decision".into(),
        Json::Str(
            match r.decision {
                CensorDecision::Transmit => "transmit",
                CensorDecision::Skip => "skip",
            }
            .into(),
        ),
    );
    o.insert("delta".into(), payload_to_json(&r.delta));
    o.insert("loss".into(), Json::Str(hex_f64(r.loss)));
    o.insert("delta_sq".into(), Json::Str(hex_f64(r.delta_sq)));
    o.insert("bits".into(), Json::Str(hex_u64(r.bits)));
    o.insert("batch_frac".into(), Json::Str(hex_f64(r.batch_frac)));
    Json::Obj(o)
}

pub(crate) fn round_from_json(v: &Json) -> Result<WorkerRound, CheckpointError> {
    let o = as_obj(v, "round")?;
    check_keys(
        o,
        &["worker", "decision", "delta", "loss", "delta_sq", "bits",
          "batch_frac"],
        &[],
        "round",
    )?;
    let decision = match str_field(o, "decision", "round")? {
        "transmit" => CensorDecision::Transmit,
        "skip" => CensorDecision::Skip,
        other => {
            return Err(CheckpointError::Corrupt(format!(
                "unknown censor decision {other:?}"
            )))
        }
    };
    Ok(WorkerRound {
        worker: num_field(o, "worker", "round")? as usize,
        decision,
        delta: Arc::new(payload_from_json(req(o, "delta", "round")?)?),
        loss: f64_from_json(req(o, "loss", "round")?, "round.loss")?,
        delta_sq: f64_from_json(req(o, "delta_sq", "round")?, "round.delta_sq")?,
        bits: u64_from_json(req(o, "bits", "round")?, "round.bits")?,
        batch_frac: f64_from_json(
            req(o, "batch_frac", "round")?,
            "round.batch_frac",
        )?,
    })
}

fn async_to_json(a: &AsyncState) -> Json {
    let mut o = BTreeMap::new();
    o.insert(
        "queue".into(),
        Json::Arr(
            a.queue
                .iter()
                .map(|e| {
                    let mut q = BTreeMap::new();
                    q.insert("time_us".into(), Json::Str(hex_f64(e.time_us)));
                    q.insert("rank".into(), Json::Num(f64::from(e.rank)));
                    q.insert("worker".into(), Json::Num(e.worker as f64));
                    q.insert("seq".into(), Json::Str(hex_u64(e.seq)));
                    let mut ev = BTreeMap::new();
                    match &e.ev {
                        EvSnap::Down => {
                            ev.insert("type".into(), Json::Str("down".into()));
                        }
                        EvSnap::Compute => {
                            ev.insert(
                                "type".into(),
                                Json::Str("compute".into()),
                            );
                        }
                        EvSnap::Up { round, version } => {
                            ev.insert("type".into(), Json::Str("up".into()));
                            ev.insert("round".into(), round_to_json(round));
                            ev.insert(
                                "version".into(),
                                Json::Num(*version as f64),
                            );
                        }
                    }
                    q.insert("ev".into(), Json::Obj(ev));
                    Json::Obj(q)
                })
                .collect(),
        ),
    );
    o.insert("seq".into(), Json::Str(hex_u64(a.seq)));
    o.insert("last_popped_us".into(), Json::Str(hex_f64(a.last_popped_us)));
    o.insert(
        "stations".into(),
        Json::Arr(
            a.stations
                .iter()
                .map(|s| {
                    let mut m = BTreeMap::new();
                    m.insert("theta".into(), hex_f64_vec(&s.theta));
                    m.insert("step_sq".into(), Json::Str(hex_f64(s.step_sq)));
                    m.insert("version".into(), Json::Num(s.version as f64));
                    Json::Obj(m)
                })
                .collect(),
        ),
    );
    o.insert("loss_cache".into(), hex_f64_vec(&a.loss_cache));
    o.insert(
        "comp_rng".into(),
        Json::Arr(a.comp_rng.iter().map(rng_to_json).collect()),
    );
    o.insert("censor_skips".into(), usize_arr_json(&a.censor_skips));
    o.insert("local_rounds".into(), usize_arr_json(&a.local_rounds));
    o.insert("applied_sum".into(), hex_f64_vec(&a.applied_sum));
    o.insert("dropped_sum".into(), hex_f64_vec(&a.dropped_sum));
    o.insert("vclock_us".into(), Json::Str(hex_f64(a.vclock_us)));
    Json::Obj(o)
}

fn async_from_json(
    v: &Json,
    dim: usize,
    m: usize,
) -> Result<AsyncState, CheckpointError> {
    let o = as_obj(v, "async")?;
    check_keys(
        o,
        &[
            "queue", "seq", "last_popped_us", "stations", "loss_cache",
            "comp_rng", "censor_skips", "local_rounds", "applied_sum",
            "dropped_sum", "vclock_us",
        ],
        &[],
        "async",
    )?;
    let queue = arr_field(o, "queue", "async")?
        .iter()
        .map(|qj| {
            let q = as_obj(qj, "async.queue entry")?;
            check_keys(
                q,
                &["time_us", "rank", "worker", "seq", "ev"],
                &[],
                "async.queue entry",
            )?;
            let evo = as_obj(req(q, "ev", "async.queue entry")?, "async ev")?;
            let ev = match str_field(evo, "type", "async ev")? {
                "down" => {
                    check_keys(evo, &["type"], &[], "async ev")?;
                    EvSnap::Down
                }
                "compute" => {
                    check_keys(evo, &["type"], &[], "async ev")?;
                    EvSnap::Compute
                }
                "up" => {
                    check_keys(
                        evo,
                        &["type", "round", "version"],
                        &[],
                        "async ev",
                    )?;
                    EvSnap::Up {
                        round: round_from_json(req(evo, "round", "async ev")?)?,
                        version: num_field(evo, "version", "async ev")? as usize,
                    }
                }
                other => {
                    return Err(CheckpointError::Corrupt(format!(
                        "unknown async event type {other:?}"
                    )))
                }
            };
            Ok(QueuedEv {
                time_us: f64_from_json(
                    req(q, "time_us", "async.queue entry")?,
                    "time_us",
                )?,
                rank: num_field(q, "rank", "async.queue entry")? as u8,
                worker: num_field(q, "worker", "async.queue entry")? as usize,
                seq: u64_from_json(req(q, "seq", "async.queue entry")?, "seq")?,
                ev,
            })
        })
        .collect::<Result<Vec<_>, CheckpointError>>()?;
    let stations = arr_field(o, "stations", "async")?
        .iter()
        .map(|sj| {
            let s = as_obj(sj, "station")?;
            check_keys(s, &["theta", "step_sq", "version"], &[], "station")?;
            let st = StationState {
                theta: f64_vec_field(s, "theta", "station")?,
                step_sq: f64_from_json(
                    req(s, "step_sq", "station")?,
                    "station.step_sq",
                )?,
                version: num_field(s, "version", "station")? as usize,
            };
            if st.theta.len() != dim {
                return Err(CheckpointError::Corrupt(format!(
                    "station theta has {} elements, dim is {dim}",
                    st.theta.len()
                )));
            }
            Ok(st)
        })
        .collect::<Result<Vec<_>, CheckpointError>>()?;
    let a = AsyncState {
        queue,
        seq: u64_from_json(req(o, "seq", "async")?, "async.seq")?,
        last_popped_us: f64_from_json(
            req(o, "last_popped_us", "async")?,
            "async.last_popped_us",
        )?,
        stations,
        loss_cache: f64_vec_field(o, "loss_cache", "async")?,
        comp_rng: arr_field(o, "comp_rng", "async")?
            .iter()
            .map(|j| rng_from_json(j, "async.comp_rng"))
            .collect::<Result<Vec<_>, _>>()?,
        censor_skips: usize_arr(
            req(o, "censor_skips", "async")?,
            "async.censor_skips",
        )?,
        local_rounds: usize_arr(
            req(o, "local_rounds", "async")?,
            "async.local_rounds",
        )?,
        applied_sum: f64_vec_field(o, "applied_sum", "async")?,
        dropped_sum: f64_vec_field(o, "dropped_sum", "async")?,
        vclock_us: f64_from_json(
            req(o, "vclock_us", "async")?,
            "async.vclock_us",
        )?,
    };
    for (name, len) in [
        ("stations", a.stations.len()),
        ("loss_cache", a.loss_cache.len()),
        ("comp_rng", a.comp_rng.len()),
        ("local_rounds", a.local_rounds.len()),
    ] {
        if len != m {
            return Err(CheckpointError::Corrupt(format!(
                "async.{name} has {len} entries for {m} workers"
            )));
        }
    }
    if !a.censor_skips.is_empty() && a.censor_skips.len() != m {
        return Err(CheckpointError::Corrupt(format!(
            "async.censor_skips has {} entries for {m} workers",
            a.censor_skips.len()
        )));
    }
    for (name, len) in
        [("applied_sum", a.applied_sum.len()), ("dropped_sum", a.dropped_sum.len())]
    {
        if len != dim {
            return Err(CheckpointError::Corrupt(format!(
                "async.{name} has {len} elements, dim is {dim}"
            )));
        }
    }
    Ok(a)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_checkpoint() -> Checkpoint {
        let dim = 3;
        let mut trace = Trace::new("CHB");
        trace.iters.push(IterStat {
            k: 1,
            loss: 1.5,
            comms_round: 2,
            comms_cum: 2,
            agg_grad_sq: 0.25,
            step_sq: 1e-3,
            bits_cum: 384,
            down_bits_cum: 384,
            vclock_us: 1000.0,
            stale_max: 0,
            batch_frac: 1.0,
            epoch: 1.0,
        });
        trace.participants.push(2);
        trace.per_worker_comms = vec![1, 1];
        trace.comm_map.push(vec![true, false]);
        Checkpoint {
            version: CHECKPOINT_VERSION,
            spec_hash: Some(fnv1a64("{}")),
            engine: "serial".into(),
            k: 1,
            dim,
            server: ServerState {
                theta: vec![0.1, -0.2, 3.0e-7],
                theta_prev: vec![0.0; 3],
                agg_grad: vec![1.0 / 3.0, 0.0, -5.5],
                k: 1,
            },
            workers: vec![
                WorkerState {
                    id: 0,
                    last_tx: vec![1.0, 2.0, 3.0],
                    transmissions: 1,
                    residual: vec![],
                },
                WorkerState {
                    id: 1,
                    last_tx: vec![0.0; 3],
                    transmissions: 1,
                    residual: vec![0.5, -0.25, 0.0],
                },
            ],
            schedule_rng: Some([1, 2, 3, u64::MAX]),
            net: NetState {
                rng: [9, 8, 7, 6],
                dropped: 4,
                sim_clock_us: 1234.5,
                up: vec![LinkState { messages: 1, bytes: 32 }; 2],
                down: vec![LinkState { messages: 1, bytes: 40 }; 2],
            },
            trace,
            async_state: None,
        }
    }

    #[test]
    fn hex_codec_is_bit_exact_for_awkward_values() {
        for x in [
            0.0,
            -0.0,
            f64::NAN,
            f64::INFINITY,
            f64::MIN_POSITIVE,
            1.0 / 3.0,
            -1e308,
        ] {
            let back =
                f64_vec_from_hex(&hex_f64(x), "t").unwrap()[0];
            assert_eq!(back.to_bits(), x.to_bits(), "{x}");
        }
        assert!(u64_from_hex("zz", "t").is_err());
        assert!(f64_vec_from_hex("0123456789abcde", "t").is_err());
    }

    #[test]
    fn checkpoint_round_trips_through_json() {
        let cp = sample_checkpoint();
        let text = cp.to_json_string();
        let back = Checkpoint::from_json_str(&text).unwrap();
        assert_eq!(back.spec_hash, cp.spec_hash);
        assert_eq!(back.engine, cp.engine);
        assert_eq!(back.k, cp.k);
        assert_eq!(back.server, cp.server);
        assert_eq!(back.workers, cp.workers);
        assert_eq!(back.schedule_rng, cp.schedule_rng);
        assert_eq!(back.net, cp.net);
        assert_eq!(back.trace.iters.len(), 1);
        assert_eq!(
            back.trace.iters[0].loss.to_bits(),
            cp.trace.iters[0].loss.to_bits()
        );
        assert_eq!(back.trace.comm_map, cp.trace.comm_map);
        // and the round trip is textually stable
        assert_eq!(back.to_json_string(), text);
    }

    #[test]
    fn payload_variants_round_trip() {
        for p in [
            Payload::Dense(vec![1.5, -2.5]),
            Payload::Sparse { idx: vec![0, 7], val: vec![3.25, -1.0] },
            Payload::Packed(PackedBuf {
                scheme: PackScheme::Int { bits: 8 },
                len: 3,
                scale: 0.125,
                words: vec![0xDEAD_BEEF],
            }),
            Payload::Packed(PackedBuf {
                scheme: PackScheme::Fp16,
                len: 2,
                scale: 1.0,
                words: vec![42],
            }),
        ] {
            let back = payload_from_json(&payload_to_json(&p)).unwrap();
            assert_eq!(back, p);
        }
    }

    #[test]
    fn version_skew_is_a_typed_error() {
        let text = sample_checkpoint()
            .to_json_string()
            .replace("\"version\": 1", "\"version\": 2");
        match Checkpoint::from_json_str(&text) {
            Err(CheckpointError::Version { found: 2, expected: 1 }) => {}
            other => panic!("expected Version error, got {other:?}"),
        }
    }

    #[test]
    fn truncation_and_unknown_keys_are_typed_errors() {
        let text = sample_checkpoint().to_json_string();
        match Checkpoint::from_json_str(&text[..text.len() / 2]) {
            Err(CheckpointError::Parse(_)) => {}
            other => panic!("expected Parse error, got {other:?}"),
        }
        let poisoned = text.replace("\"engine\"", "\"enigne\"");
        match Checkpoint::from_json_str(&poisoned) {
            Err(CheckpointError::Corrupt(_)) => {}
            other => panic!("expected Corrupt error, got {other:?}"),
        }
    }

    #[test]
    fn compat_check_distinguishes_failure_modes() {
        let cp = sample_checkpoint();
        assert!(cp.check_compat(cp.spec_hash, "serial", 3, 2).is_ok());
        // raw runs without a hash interoperate
        assert!(cp.check_compat(None, "serial", 3, 2).is_ok());
        assert!(matches!(
            cp.check_compat(Some(1), "serial", 3, 2),
            Err(CheckpointError::SpecMismatch { .. })
        ));
        assert!(matches!(
            cp.check_compat(cp.spec_hash, "rayon", 3, 2),
            Err(CheckpointError::Engine { .. })
        ));
        assert!(matches!(
            cp.check_compat(cp.spec_hash, "serial", 4, 2),
            Err(CheckpointError::Dimension { found: 3, expected: 4 })
        ));
        assert!(matches!(
            cp.check_compat(cp.spec_hash, "serial", 3, 5),
            Err(CheckpointError::Corrupt(_))
        ));
    }

    #[test]
    fn save_is_atomic_and_load_round_trips() {
        let dir = std::env::temp_dir().join(format!(
            "chb_ckpt_test_{}_{}",
            std::process::id(),
            fnv1a64("save_is_atomic")
        ));
        let path = dir.join("nested").join("checkpoint.json");
        let cp = sample_checkpoint();
        cp.save(&path).unwrap();
        // the temp file must be gone after the rename
        let mut tmp = path.as_os_str().to_owned();
        tmp.push(".tmp");
        assert!(!PathBuf::from(tmp).exists());
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.server, cp.server);
        // overwrite in place succeeds (the resume loop's steady state)
        cp.save(&path).unwrap();
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn atomic_write_survives_a_kill_mid_write() {
        let dir = std::env::temp_dir().join(format!(
            "chb_ckpt_test_{}_{}",
            std::process::id(),
            fnv1a64("atomic_write_torn")
        ));
        let path = dir.join("artifact.json");
        atomic_write(&path, "{\"ok\": 1}\n").unwrap();
        // simulate a process killed mid-write: a torn temp file next to
        // a complete artifact.  The artifact must still parse cleanly,
        // and the next atomic_write must replace both.
        let mut tmp = path.as_os_str().to_owned();
        tmp.push(".tmp");
        let tmp = PathBuf::from(tmp);
        std::fs::write(&tmp, "{\"ok\": 2").unwrap(); // truncated JSON
        let text = std::fs::read_to_string(&path).unwrap();
        Json::parse(&text).unwrap();
        assert_eq!(text, "{\"ok\": 1}\n");
        atomic_write(&path, "{\"ok\": 3}\n").unwrap();
        assert!(!tmp.exists(), "rename must consume the temp file");
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "{\"ok\": 3}\n");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn missing_file_is_an_io_error() {
        let err = Checkpoint::load(Path::new(
            "/nonexistent/chb/checkpoint.json",
        ))
        .unwrap_err();
        assert!(matches!(err, CheckpointError::Io(_)));
    }

    #[test]
    fn policy_cadence_and_path() {
        let p = CheckpointPolicy::new(10, "/tmp/ckpts");
        assert!(!p.due(5));
        assert!(p.due(10));
        assert!(p.due(20));
        assert!(!p.due(0) || p.every == 0);
        assert_eq!(p.path(), PathBuf::from("/tmp/ckpts/checkpoint.json"));
        let never = CheckpointPolicy::new(0, "/tmp");
        assert!(!never.due(10));
    }

    #[test]
    fn fnv1a64_matches_reference_vectors() {
        // standard FNV-1a test vectors
        assert_eq!(fnv1a64(""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64("a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64("foobar"), 0x85944171f73967e8);
        assert_ne!(fnv1a64("{\"a\":1}"), fnv1a64("{\"a\":2}"));
    }
}
