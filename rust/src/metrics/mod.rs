//! Per-run instrumentation: the numbers every figure/table plots.

pub mod csv;
pub mod summary;

pub use summary::{Histogram, PopulationSummary, Reservoir};

/// One iteration's record.
#[derive(Clone, Debug)]
pub struct IterStat {
    /// server iteration index (1-based)
    pub k: usize,
    /// f(θᵏ) = Σ_m f_m(θᵏ) (async engines: Σ of each worker's most
    /// recently reported loss, evaluated at its own iterate copy)
    pub loss: f64,
    /// uplink transmissions this iteration |Mᵏ|
    pub comms_round: usize,
    /// cumulative uplink transmissions through iteration k
    pub comms_cum: usize,
    /// ‖∇ᵏ‖² (the server's aggregate; the paper's NN figure of merit)
    pub agg_grad_sq: f64,
    /// ‖θ^{k+1} − θᵏ‖²
    pub step_sq: f64,
    /// cumulative uplink payload bits (compression-aware)
    pub bits_cum: u64,
    /// cumulative downlink payload bits: every scheduled worker's
    /// broadcast charged per round (64·d uncompressed, the codec's
    /// honest size under `downlink` compression) — kept separate from
    /// `bits_cum` so the uplink-only ledger stays comparable with the
    /// paper and with pre-downlink traces
    pub down_bits_cum: u64,
    /// virtual-clock time (µs) at which this server step completed —
    /// event time in the async engine, accumulated [`LatencyModel`]
    /// round time in the synchronous engines
    ///
    /// [`LatencyModel`]: crate::net::LatencyModel
    pub vclock_us: f64,
    /// largest arrival staleness (in server steps between the iterate
    /// a delta was computed at and the fold) among this step's folded
    /// deltas; always 0 under synchronous rounds
    pub stale_max: usize,
    /// mean shard fraction among the workers that computed a gradient
    /// this step (loss-only observers are excluded): 1.0 in the
    /// full-batch regime, |B|/n under minibatch schedules (> 1 when a
    /// with-replacement draw oversamples the shard — see `data::batch`)
    pub batch_frac: f64,
    /// cumulative global data passes consumed through this step
    /// (Σ per-worker shard fractions / M per round) — the x-axis
    /// stochastic traces are read against; equals k in the legacy
    /// full-batch full-participation regime
    pub epoch: f64,
}

/// Per-worker arrival-staleness telemetry (async engine).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StalenessStats {
    /// deltas from this worker folded into the aggregate
    pub folds: usize,
    /// largest staleness (server steps) over those folds
    pub max: usize,
    /// summed staleness over those folds (for the mean)
    pub sum: usize,
}

impl StalenessStats {
    /// Record one fold with arrival staleness `s`.
    pub fn record(&mut self, s: usize) {
        self.folds += 1;
        self.max = self.max.max(s);
        self.sum += s;
    }

    /// Mean staleness over all folds (NaN when the worker never folded).
    pub fn mean(&self) -> f64 {
        if self.folds == 0 {
            return f64::NAN;
        }
        self.sum as f64 / self.folds as f64
    }
}

/// Full trace of a run.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    /// method label ("CHB", "HB", …, or a custom ablation label)
    pub method: String,
    /// one record per server iteration
    pub iters: Vec<IterStat>,
    /// per-worker lifetime transmission counts S_m (Lemma 2)
    pub per_worker_comms: Vec<usize>,
    /// scheduled workers per round |Pᵏ| (== M under the paper's full
    /// participation; smaller under sampling/straggler schedules; the
    /// async engine records reports folded per server step)
    pub participants: Vec<usize>,
    /// per-(iteration, worker) transmit map for Fig. 1-style plots;
    /// only recorded when `record_comm_map` is on (it is O(K·M))
    pub comm_map: Vec<Vec<bool>>,
    /// per-worker arrival-staleness telemetry; empty for synchronous
    /// runs (where staleness is identically zero)
    pub worker_staleness: Vec<StalenessStats>,
    /// worker-round crash events injected by the fault plan (a worker
    /// counted once per round it was forced down); 0 without faults
    pub fault_downs: usize,
    /// forced rejoin transmissions injected by the fault plan (each
    /// one re-synced a worker's censor reference θ̂ before reporting)
    pub fault_rejoins: usize,
}

impl Trace {
    /// Empty trace labelled with the method's name.
    pub fn new(method: &str) -> Self {
        Self { method: method.to_string(), ..Default::default() }
    }

    /// Total delivered uplink transmissions over the whole run.
    pub fn total_comms(&self) -> usize {
        self.iters.last().map_or(0, |s| s.comms_cum)
    }

    /// Total uplink payload bits over the whole run.
    pub fn total_uplink_bits(&self) -> u64 {
        self.iters.last().map_or(0, |s| s.bits_cum)
    }

    /// Total downlink payload bits over the whole run.
    pub fn total_downlink_bits(&self) -> u64 {
        self.iters.last().map_or(0, |s| s.down_bits_cum)
    }

    /// f(θ) at the final iteration (NaN for an empty trace).
    pub fn final_loss(&self) -> f64 {
        self.iters.last().map_or(f64::NAN, |s| s.loss)
    }

    /// Number of recorded server iterations.
    pub fn iterations(&self) -> usize {
        self.iters.len()
    }

    /// Largest arrival staleness seen anywhere in the run (0 for
    /// synchronous runs).
    pub fn max_staleness(&self) -> usize {
        self.worker_staleness.iter().map(|s| s.max).max().unwrap_or(0)
    }

    /// Mean scheduled workers per round (NaN when unrecorded).
    pub fn mean_participants(&self) -> f64 {
        if self.participants.is_empty() {
            return f64::NAN;
        }
        self.participants.iter().sum::<usize>() as f64
            / self.participants.len() as f64
    }

    /// Objective error trajectory f(θᵏ) − f*.
    pub fn obj_errors(&self, f_star: f64) -> Vec<f64> {
        self.iters.iter().map(|s| s.loss - f_star).collect()
    }

    /// First iteration k with f(θᵏ) − f* < tol, with the cumulative
    /// comms spent to get there — the numbers in Tables I/II.
    pub fn first_below(&self, f_star: f64, tol: f64) -> Option<(usize, usize)> {
        self.iters
            .iter()
            .find(|s| s.loss - f_star < tol)
            .map(|s| (s.k, s.comms_cum))
    }

    /// Averaged per-communication descent (paper Fig. 12):
    /// (f(θ⁰) − f(θᵏ)) / comms_cum(k), evaluated at iteration k.
    pub fn per_comm_descent(&self, f_theta0: f64) -> Vec<(usize, f64, f64)> {
        self.iters
            .iter()
            .filter(|s| s.comms_cum > 0)
            .map(|s| (s.k, s.loss, (f_theta0 - s.loss) / s.comms_cum as f64))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stat(k: usize, loss: f64, comms_round: usize, comms_cum: usize) -> IterStat {
        IterStat {
            k,
            loss,
            comms_round,
            comms_cum,
            agg_grad_sq: 0.0,
            step_sq: 0.0,
            bits_cum: 0,
            down_bits_cum: 0,
            vclock_us: 0.0,
            stale_max: 0,
            batch_frac: 1.0,
            epoch: k as f64,
        }
    }

    #[test]
    fn first_below_finds_threshold_crossing() {
        let mut t = Trace::new("CHB");
        t.iters = vec![
            stat(1, 10.0, 9, 9),
            stat(2, 1.0, 4, 13),
            stat(3, 0.5, 2, 15),
        ];
        // f* = 0.4, tol = 1 ⇒ first loss−f* < 1 is k=2 (1.0−0.4=0.6)
        assert_eq!(t.first_below(0.4, 1.0), Some((2, 13)));
        assert_eq!(t.first_below(0.0, 0.1), None);
        assert_eq!(t.total_comms(), 15);
    }

    #[test]
    fn staleness_stats_track_max_and_mean() {
        let mut s = StalenessStats::default();
        assert!(s.mean().is_nan());
        s.record(0);
        s.record(4);
        s.record(2);
        assert_eq!(s.folds, 3);
        assert_eq!(s.max, 4);
        assert!((s.mean() - 2.0).abs() < 1e-15);
        let mut t = Trace::new("CHB-async");
        t.worker_staleness = vec![StalenessStats::default(), s];
        assert_eq!(t.max_staleness(), 4);
        assert_eq!(Trace::new("CHB").max_staleness(), 0);
    }

    #[test]
    fn per_comm_descent_divides_by_cumulative() {
        let mut t = Trace::new("CHB");
        t.iters = vec![stat(1, 8.0, 2, 2), stat(2, 6.0, 1, 3)];
        let d = t.per_comm_descent(10.0);
        assert_eq!(d.len(), 2);
        assert!((d[0].2 - 1.0).abs() < 1e-15); // (10−8)/2
        assert!((d[1].2 - (4.0 / 3.0)).abs() < 1e-15);
    }
}
