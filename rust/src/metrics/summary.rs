//! Bounded-memory telemetry for population-scale runs.
//!
//! At M = 10⁶ clients, per-client trace columns (the O(K·M) comm map,
//! per-worker staleness rows) are exactly the memory the population
//! engine exists to avoid.  This module provides the two streaming
//! summaries it records instead — a seeded [`Reservoir`] sample for
//! continuous statistics and a saturating [`Histogram`] for small
//! integer statistics — plus [`PopulationSummary`], the fixed-size
//! bundle a population run reports next to its O(rounds) trace.
//!
//! Both structures are deterministic: the reservoir draws from a
//! seeded [`Xoshiro256`] stream, so two runs of the same spec produce
//! bit-identical summaries regardless of population size or queue
//! backend.

use crate::rng::Xoshiro256;

/// Algorithm-R reservoir sample: a uniform `cap`-element sample of an
/// unbounded stream in O(cap) memory, deterministic from `seed`.
#[derive(Clone, Debug)]
pub struct Reservoir {
    sample: Vec<f64>,
    cap: usize,
    seen: u64,
    rng: Xoshiro256,
}

impl Reservoir {
    /// Empty reservoir holding at most `cap` values (`cap` ≥ 1).
    pub fn new(cap: usize, seed: u64) -> Self {
        assert!(cap >= 1, "reservoir capacity must be ≥ 1");
        Self {
            sample: Vec::with_capacity(cap),
            cap,
            seen: 0,
            rng: Xoshiro256::new(seed),
        }
    }

    /// Offer one value to the reservoir.
    pub fn record(&mut self, x: f64) {
        self.seen += 1;
        if self.sample.len() < self.cap {
            self.sample.push(x);
        } else {
            // Algorithm R: keep x with probability cap/seen
            let j = self.rng.next_below(self.seen);
            if (j as usize) < self.cap {
                self.sample[j as usize] = x;
            }
        }
    }

    /// Stream length so far (not the sample size).
    pub fn seen(&self) -> u64 {
        self.seen
    }

    /// The current sample (≤ cap values, unordered).
    pub fn sample(&self) -> &[f64] {
        &self.sample
    }

    /// Empirical `q`-quantile of the sample (nearest-rank on a sorted
    /// copy); NaN for an empty reservoir.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.sample.is_empty() {
            return f64::NAN;
        }
        let mut s = self.sample.clone();
        s.sort_by(f64::total_cmp);
        let i = ((q.clamp(0.0, 1.0) * (s.len() - 1) as f64).round()) as usize;
        s[i]
    }

    /// Mean of the sample (NaN when empty).
    pub fn mean(&self) -> f64 {
        if self.sample.is_empty() {
            return f64::NAN;
        }
        self.sample.iter().sum::<f64>() / self.sample.len() as f64
    }
}

/// Saturating linear histogram over small non-negative integers:
/// value `v` lands in bucket `v`, values ≥ the bucket count land in
/// the overflow bucket.  O(buckets) memory regardless of stream size.
#[derive(Clone, Debug)]
pub struct Histogram {
    counts: Vec<u64>,
    overflow: u64,
    max: usize,
}

impl Histogram {
    /// Histogram with `buckets` exact buckets (values 0..buckets).
    pub fn new(buckets: usize) -> Self {
        Self { counts: vec![0; buckets.max(1)], overflow: 0, max: 0 }
    }

    /// Record one observation.
    pub fn record(&mut self, v: usize) {
        match self.counts.get_mut(v) {
            Some(c) => *c += 1,
            None => self.overflow += 1,
        }
        self.max = self.max.max(v);
    }

    /// Total observations recorded.
    pub fn total(&self) -> u64 {
        self.counts.iter().sum::<u64>() + self.overflow
    }

    /// Largest value observed (exact even for overflowed values).
    pub fn max(&self) -> usize {
        self.max
    }

    /// Observations that landed past the last exact bucket.
    pub fn overflow(&self) -> u64 {
        self.overflow
    }

    /// Per-bucket counts (bucket i = value i).
    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    /// Nearest-rank `q`-quantile.  Overflowed mass reports as the
    /// observed maximum; an empty histogram reports 0.
    pub fn quantile(&self, q: f64) -> usize {
        let total = self.total();
        if total == 0 {
            return 0;
        }
        let rank = (q.clamp(0.0, 1.0) * (total - 1) as f64).round() as u64;
        let mut cum = 0u64;
        for (v, &c) in self.counts.iter().enumerate() {
            cum += c;
            if c > 0 && cum > rank {
                return v;
            }
        }
        self.max
    }

    /// Mean value (overflowed observations contribute the observed
    /// maximum — a lower-bound approximation); NaN when empty.
    pub fn mean(&self) -> f64 {
        let total = self.total();
        if total == 0 {
            return f64::NAN;
        }
        let sum: f64 = self
            .counts
            .iter()
            .enumerate()
            .map(|(v, &c)| v as f64 * c as f64)
            .sum::<f64>()
            + self.overflow as f64 * self.max as f64;
        sum / total as f64
    }
}

/// Fixed-size telemetry bundle for one population run: everything the
/// per-client columns used to carry, summarized in O(buckets + cap)
/// memory independent of both M and the round count.
#[derive(Clone, Debug)]
pub struct PopulationSummary {
    /// population size M
    pub clients: u64,
    /// per-round cohort size
    pub cohort: u64,
    /// server rounds executed
    pub rounds: usize,
    /// delivered uplink transmissions over the run
    pub uplinks: u64,
    /// cohort slots that stayed silent (censored)
    pub censored: u64,
    /// lazy censor-reference rematerializations performed
    pub resyncs: u64,
    /// censor-reference age (rounds since the client last transmitted)
    /// at each cohort materialization; 0 for first-contact clients
    pub reference_age: Histogram,
    /// per-client lifetime transmission counts (filled once, at exit)
    pub tx_per_client: Histogram,
    /// reservoir sample of ‖δ∇‖² across all cohort evaluations
    pub delta_sq: Reservoir,
}

impl PopulationSummary {
    /// Empty summary for an (M, cohort) population.
    pub fn new(clients: u64, cohort: u64) -> Self {
        Self {
            clients,
            cohort,
            rounds: 0,
            uplinks: 0,
            censored: 0,
            resyncs: 0,
            reference_age: Histogram::new(256),
            tx_per_client: Histogram::new(256),
            delta_sq: Reservoir::new(1024, 0x5ca1e),
        }
    }

    /// Fraction of cohort evaluations the censor silenced — the
    /// communication the population saved.
    pub fn censor_rate(&self) -> f64 {
        let evals = self.uplinks + self.censored;
        if evals == 0 {
            return 0.0;
        }
        self.censored as f64 / evals as f64
    }

    /// Summary as (name, value) rows for CSV / CLI reporting.
    pub fn rows(&self) -> Vec<(String, f64)> {
        vec![
            ("clients".into(), self.clients as f64),
            ("cohort".into(), self.cohort as f64),
            ("rounds".into(), self.rounds as f64),
            ("uplinks".into(), self.uplinks as f64),
            ("censored".into(), self.censored as f64),
            ("censor_rate".into(), self.censor_rate()),
            ("resyncs".into(), self.resyncs as f64),
            ("ref_age_mean".into(), self.reference_age.mean()),
            ("ref_age_p99".into(), self.reference_age.quantile(0.99) as f64),
            ("ref_age_max".into(), self.reference_age.max() as f64),
            ("tx_per_client_mean".into(), self.tx_per_client.mean()),
            (
                "tx_per_client_p99".into(),
                self.tx_per_client.quantile(0.99) as f64,
            ),
            ("delta_sq_mean".into(), self.delta_sq.mean()),
            ("delta_sq_p99".into(), self.delta_sq.quantile(0.99)),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reservoir_keeps_everything_under_capacity() {
        let mut r = Reservoir::new(10, 1);
        for i in 0..5 {
            r.record(i as f64);
        }
        assert_eq!(r.sample().len(), 5);
        assert_eq!(r.seen(), 5);
        assert_eq!(r.quantile(0.0), 0.0);
        assert_eq!(r.quantile(1.0), 4.0);
        assert!((r.mean() - 2.0).abs() < 1e-15);
    }

    #[test]
    fn reservoir_is_bounded_and_deterministic() {
        let run = || {
            let mut r = Reservoir::new(8, 42);
            for i in 0..10_000 {
                r.record(i as f64);
            }
            r.sample().to_vec()
        };
        let a = run();
        let b = run();
        assert_eq!(a.len(), 8);
        assert_eq!(a, b, "same seed, same stream ⇒ same sample");
    }

    #[test]
    fn reservoir_sample_is_roughly_uniform() {
        // the median of a uniform sample of 0..10000 should be central
        let mut r = Reservoir::new(512, 7);
        for i in 0..10_000 {
            r.record(i as f64);
        }
        let med = r.quantile(0.5);
        assert!((2000.0..8000.0).contains(&med), "median {med}");
    }

    #[test]
    fn histogram_counts_quantiles_and_overflow() {
        let mut h = Histogram::new(4);
        for v in [0, 0, 1, 2, 3, 9] {
            h.record(v);
        }
        assert_eq!(h.total(), 6);
        assert_eq!(h.overflow(), 1); // the 9
        assert_eq!(h.max(), 9);
        assert_eq!(h.quantile(0.0), 0);
        assert_eq!(h.quantile(1.0), 9); // overflow reports the max
        assert_eq!(h.counts(), &[2, 1, 1, 1]);
    }

    #[test]
    fn empty_summaries_do_not_divide_by_zero() {
        let s = PopulationSummary::new(100, 10);
        assert_eq!(s.censor_rate(), 0.0);
        assert_eq!(Histogram::new(4).quantile(0.5), 0);
        assert!(Reservoir::new(4, 0).quantile(0.5).is_nan());
        assert!(s.rows().len() >= 10);
    }

    #[test]
    fn censor_rate_is_censored_over_evaluations() {
        let mut s = PopulationSummary::new(100, 10);
        s.uplinks = 30;
        s.censored = 70;
        assert!((s.censor_rate() - 0.7).abs() < 1e-15);
    }
}
