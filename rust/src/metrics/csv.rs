//! CSV writers for traces and tables (no external crates).
//!
//! Every writer builds the full document in memory and lands it with
//! [`crate::checkpoint::atomic_write`] (tmp file + rename), so a
//! crash mid-write never leaves a torn CSV behind — downstream
//! plotting and `tools/bench_diff.py` either see the old file or the
//! complete new one.

use std::fmt::Write;
use std::path::Path;

use anyhow::{Context, Result};

use crate::checkpoint::atomic_write;

use super::Trace;

/// Write one trace: k, loss, obj_err, comms_round, comms_cum, …,
/// plus the virtual-clock and staleness columns the async engine
/// fills (synchronous engines write the accumulated round latency
/// and stale_max = 0).
pub fn write_trace(path: &Path, trace: &Trace, f_star: f64) -> Result<()> {
    let mut out = String::from(
        "k,loss,obj_err,comms_round,comms_cum,agg_grad_sq,step_sq,bits_cum,\
         participants,vclock_us,stale_max,batch_frac,epoch,downlink_bits_cum\n",
    );
    for (i, s) in trace.iters.iter().enumerate() {
        writeln!(
            out,
            "{},{:.17e},{:.17e},{},{},{:.17e},{:.17e},{},{},{:.6},{},{:.6},{:.6},{}",
            s.k,
            s.loss,
            s.loss - f_star,
            s.comms_round,
            s.comms_cum,
            s.agg_grad_sq,
            s.step_sq,
            s.bits_cum,
            // 0 = unrecorded (traces assembled outside the engine)
            trace.participants.get(i).copied().unwrap_or(0),
            s.vclock_us,
            s.stale_max,
            s.batch_frac,
            s.epoch,
            s.down_bits_cum
        )
        .expect("String writes cannot fail");
    }
    atomic_write(path, &out)
        .with_context(|| format!("write {}", path.display()))
}

/// Write the per-worker staleness telemetry (async runs): one row per
/// worker with its fold count, max and mean arrival staleness.
pub fn write_staleness(path: &Path, trace: &Trace) -> Result<()> {
    let mut out = String::from("worker,folds,stale_max,stale_mean\n");
    for (id, s) in trace.worker_staleness.iter().enumerate() {
        writeln!(out, "{},{},{},{:.6}", id, s.folds, s.max, s.mean())
            .expect("String writes cannot fail");
    }
    atomic_write(path, &out)
        .with_context(|| format!("write {}", path.display()))
}

/// Write the per-(iteration, worker) transmit map (Fig. 1).
pub fn write_comm_map(path: &Path, trace: &Trace) -> Result<()> {
    let m = trace.comm_map.first().map_or(0, |r| r.len());
    let header: Vec<String> = (0..m).map(|i| format!("w{i}")).collect();
    let mut out = format!("k,{}\n", header.join(","));
    for (k, row) in trace.comm_map.iter().enumerate() {
        let cells: Vec<&str> =
            row.iter().map(|&b| if b { "1" } else { "0" }).collect();
        writeln!(out, "{},{}", k + 1, cells.join(","))
            .expect("String writes cannot fail");
    }
    atomic_write(path, &out)
        .with_context(|| format!("write {}", path.display()))
}

/// Generic table writer: header + rows of strings.
pub fn write_table(
    path: &Path,
    header: &[&str],
    rows: &[Vec<String>],
) -> Result<()> {
    let mut out = format!("{}\n", header.join(","));
    for row in rows {
        writeln!(out, "{}", row.join(","))
            .expect("String writes cannot fail");
    }
    atomic_write(path, &out)
        .with_context(|| format!("write {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::IterStat;

    #[test]
    fn trace_csv_round_trips_basic_fields() {
        let mut t = Trace::new("HB");
        t.iters.push(IterStat {
            k: 1,
            loss: 2.5,
            comms_round: 3,
            comms_cum: 3,
            agg_grad_sq: 1.0,
            step_sq: 0.5,
            bits_cum: 0,
            down_bits_cum: 512,
            vclock_us: 1234.5,
            stale_max: 2,
            batch_frac: 0.25,
            epoch: 0.25,
        });
        let dir = std::env::temp_dir().join("chb_csv_test");
        let path = dir.join("t.csv");
        write_trace(&path, &t, 0.5).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let mut lines = text.lines();
        let header = lines.next().unwrap();
        assert!(header.starts_with("k,loss"));
        assert!(header.ends_with("stale_max,batch_frac,epoch,downlink_bits_cum"));
        let row = lines.next().unwrap();
        assert!(row.starts_with("1,"));
        assert!(row.contains(",3,3,"));
        assert!(row.ends_with(",1234.500000,2,0.250000,0.250000,512"));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn staleness_csv_has_one_row_per_worker() {
        use crate::metrics::StalenessStats;
        let mut t = Trace::new("CHB-async");
        let mut s = StalenessStats::default();
        s.record(3);
        s.record(1);
        t.worker_staleness = vec![StalenessStats::default(), s];
        let dir = std::env::temp_dir().join("chb_csv_test3");
        let path = dir.join("stale.csv");
        write_staleness(&path, &t).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines[0], "worker,folds,stale_max,stale_mean");
        assert!(lines[1].starts_with("0,0,0,"));
        assert!(lines[2].starts_with("1,2,3,2.0"));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn comm_map_encodes_bools() {
        let mut t = Trace::new("CHB");
        t.comm_map = vec![vec![true, false], vec![false, true]];
        let dir = std::env::temp_dir().join("chb_csv_test2");
        let path = dir.join("m.csv");
        write_comm_map(&path, &t).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 3);
        assert!(text.contains("1,1,0"));
        assert!(text.contains("2,0,1"));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn csv_writes_never_leave_partial_files_behind() {
        // a pre-existing file stays intact until the new content has
        // fully landed: no moment at which the path holds a prefix
        let dir = std::env::temp_dir().join("chb_csv_test4");
        let path = dir.join("table.csv");
        write_table(&path, &["a", "b"], &[vec!["1".into(), "2".into()]])
            .unwrap();
        let before = std::fs::read_to_string(&path).unwrap();
        assert_eq!(before, "a,b\n1,2\n");
        write_table(&path, &["a", "b"], &[vec!["3".into(), "4".into()]])
            .unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "a,b\n3,4\n");
        // no stray tmp files survive a completed write
        let stray: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name() != "table.csv")
            .collect();
        assert!(stray.is_empty(), "{stray:?}");
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
