//! The paper's theory as executable checks.
//!
//! * [`ParamChoice`] — the admissible (α, β, ε₁) regions of Lemma 1:
//!   conditions (10)–(12) and the closed-form corollaries (14)/(43),
//!   (44), and the Theorem-1 setting (55)/(17).
//! * [`LyapunovTracker`] — 𝕃(θᵏ) of eq. (9) with the monotonicity
//!   check of Lemma 1.
//! * [`lemma2_bound`] — the S_m ≤ k/2 communication bound.
//! * [`chb_iteration_complexity`] — eq. (59).

/// σ₀, σ₁, γ of (10)–(12) for a given parameter setting.
#[derive(Clone, Copy, Debug)]
pub struct LemmaConstants {
    /// σ₀ of condition (10) — must be > 0
    pub sigma0: f64,
    /// σ₁ of condition (11) — must be > 0
    pub sigma1: f64,
    /// γ of condition (12)
    pub gamma: f64,
}

/// A full CHB parameter choice to validate against Lemma 1.
#[derive(Clone, Copy, Debug)]
pub struct ParamChoice {
    /// step size α
    pub alpha: f64,
    /// momentum coefficient β
    pub beta: f64,
    /// censor threshold ε₁
    pub epsilon1: f64,
    /// Lyapunov weight η₁ ≥ (1−αL)/(2α) (eq. 9 / Lemma 1 hypothesis)
    pub eta1: f64,
    /// Young's-inequality free parameters (ρ₁, ρ₂, ρ₃ > 0)
    pub rho: (f64, f64, f64),
}

impl ParamChoice {
    /// The closed-form family (43): η₁ = (1−αL)/(2α), ρ₃ free.
    /// Given α ≤ 1/L and ρ₃, picks the largest admissible β and ε₁
    /// scaled by `beta_frac`/`eps_frac` ∈ (0, 1].
    pub fn closed_form_43(
        l: f64,
        alpha: f64,
        rho3: f64,
        beta_frac: f64,
        eps_frac: f64,
        m_c_max: usize,
    ) -> ParamChoice {
        assert!(alpha <= 1.0 / l, "need α ≤ 1/L");
        let eta1 = (1.0 - alpha * l) / (2.0 * alpha);
        let beta_max = ((1.0 - alpha * l) / (1.0 + 1.0 / rho3)).sqrt();
        let beta = beta_frac * beta_max;
        let eps_max = ((1.0 - alpha * l) - beta * beta * (1.0 + 1.0 / rho3))
            / (alpha * alpha * (1.0 + rho3) * (m_c_max * m_c_max) as f64);
        ParamChoice {
            alpha,
            beta,
            epsilon1: eps_frac * eps_max.max(0.0),
            eta1,
            rho: (1.0, 1.0, rho3),
        }
    }

    /// The Theorem-1 setting (55): ρ₃ = 1, α = (1−δ)/L,
    /// ε₁ = (1−αL)(1−αμ)/(4α²M²), β = ½√((1−αL)(1−αμ)).
    pub fn theorem1_setting(l: f64, mu: f64, delta: f64, m: usize) -> ParamChoice {
        assert!((0.0..1.0).contains(&delta));
        let alpha = (1.0 - delta) / l;
        let a_l = alpha * l;
        let a_mu = alpha * mu;
        ParamChoice {
            alpha,
            beta: 0.5 * ((1.0 - a_l) * (1.0 - a_mu)).sqrt(),
            epsilon1: (1.0 - a_l) * (1.0 - a_mu)
                / (4.0 * alpha * alpha * (m * m) as f64),
            eta1: (1.0 - a_l) / (2.0 * alpha),
            rho: (1.0, 1.0, 1.0),
        }
    }

    /// Evaluate σ₀ (10), σ₁ (11), γ (12) for worst-case |M_c| = m_c.
    pub fn lemma1_constants(&self, l: f64, m_c: usize) -> LemmaConstants {
        let (r1, r2, r3) = self.rho;
        let a = self.alpha;
        let excess = self.eta1 - (1.0 - a * l) / (2.0 * a); // η₁ − (1−αL)/(2α)
        let gamma = a / 2.0 * (1.0 + r3)
            + excess * a * a * (1.0 + r1) * (1.0 + 1.0 / r2);
        let sigma0 = a / 2.0 - excess * a * a * (1.0 + r1) * (1.0 + r2);
        let sigma1 = -gamma * ((m_c * m_c) as f64) * self.epsilon1
            - self.beta * self.beta / (2.0 * a) * (1.0 + 1.0 / r3)
            - excess * self.beta * self.beta * (1.0 + 1.0 / r1)
            + self.eta1;
        LemmaConstants { sigma0, sigma1, gamma }
    }

    /// Does this choice satisfy Lemma 1 with σ₀, σ₁ > 0 for every
    /// possible censored-set size 0..=m (strict, as Theorems 1–3 need)?
    pub fn satisfies_lemma1(&self, l: f64, m: usize) -> bool {
        if self.eta1 < (1.0 - self.alpha * l) / (2.0 * self.alpha) {
            return false; // Lemma 1's hypothesis η₁ − (1−αL)/(2α) ≥ 0
        }
        // σ₁ is decreasing in |M_c|, σ₀ is independent of it:
        let worst = self.lemma1_constants(l, m);
        worst.sigma0 > 0.0 && worst.sigma1 > 0.0
    }

    /// Theorem-1 contraction factor c(α, β, ε₁) = min{2σ₀μ, min_k σ₁/η₁}.
    pub fn contraction(&self, l: f64, mu: f64, m: usize) -> f64 {
        let worst = self.lemma1_constants(l, m);
        let c = (2.0 * worst.sigma0 * mu).min(worst.sigma1 / self.eta1);
        c.clamp(0.0, 1.0)
    }
}

/// Theorem-1 corollary (17): with the (55) setting the rate is
/// c = (1−δ)/(L/μ) = αμ.
pub fn theorem1_rate(l: f64, mu: f64, delta: f64) -> f64 {
    (1.0 - delta) / (l / mu)
}

/// Iteration complexity (59): 𝕀(ε) = (L/μ)/(1−δ) · log(1/ε).
pub fn chb_iteration_complexity(l: f64, mu: f64, delta: f64, eps: f64) -> f64 {
    (l / mu) / (1.0 - delta) * (1.0 / eps).ln()
}

/// Lemma 2: if L_m² ≤ ε₁ then S_m ≤ k/2 after k iterations.
pub fn lemma2_applies(l_m: f64, epsilon1: f64) -> bool {
    l_m * l_m <= epsilon1
}

/// The Lemma-2 bound on worker m's transmissions after k iterations.
pub fn lemma2_bound(k: usize) -> usize {
    k.div_ceil(2)
}

/// Lyapunov function 𝕃(θᵏ) = f(θᵏ) − f* + η₁‖θᵏ − θ^{k−1}‖² (eq. 9),
/// tracked across a run to verify Lemma 1's monotone descent.
pub struct LyapunovTracker {
    /// Lyapunov weight η₁ on the ‖θᵏ − θ^{k−1}‖² term
    pub eta1: f64,
    /// optimal objective value f*
    pub f_star: f64,
    values: Vec<f64>,
}

impl LyapunovTracker {
    /// Tracker for 𝕃 with weight `eta1` against optimum `f_star`.
    pub fn new(eta1: f64, f_star: f64) -> Self {
        Self { eta1, f_star, values: Vec::new() }
    }

    /// Record iteration k from f(θᵏ) and ‖θᵏ − θ^{k−1}‖².
    pub fn record(&mut self, loss: f64, step_sq_prev: f64) -> f64 {
        let v = loss - self.f_star + self.eta1 * step_sq_prev;
        self.values.push(v);
        v
    }

    /// The recorded 𝕃(θᵏ) sequence.
    pub fn values(&self) -> &[f64] {
        &self.values
    }

    /// Fraction of steps that increased 𝕃 beyond tolerance — Lemma 1
    /// says this should be 0 under conditions (10)–(12).
    pub fn violation_fraction(&self, rel_tol: f64) -> f64 {
        if self.values.len() < 2 {
            return 0.0;
        }
        let violations = self
            .values
            .windows(2)
            .filter(|w| w[1] > w[0] * (1.0 + rel_tol) + rel_tol)
            .count();
        violations as f64 / (self.values.len() - 1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn closed_form_43_satisfies_lemma1() {
        let l = 10.0;
        for &af in &[0.3, 0.6, 0.9] {
            let p = ParamChoice::closed_form_43(l, af / l, 1.0, 0.5, 0.5, 9);
            assert!(
                p.satisfies_lemma1(l, 9),
                "α={af}/L: {:?}",
                p.lemma1_constants(l, 9)
            );
        }
    }

    #[test]
    fn theorem1_setting_satisfies_lemma1_and_rate() {
        let (l, mu, m) = (8.0, 0.5, 9);
        for &delta in &[0.1, 0.5, 0.9] {
            let p = ParamChoice::theorem1_setting(l, mu, delta, m);
            assert!(p.satisfies_lemma1(l, m), "δ={delta}");
            // paper (56): with this setting c = αμ = (1−δ)μ/L
            let c = p.contraction(l, mu, m);
            let want = theorem1_rate(l, mu, delta);
            assert!(
                (c - want).abs() < 1e-9,
                "δ={delta}: c={c} want {want}"
            );
        }
    }

    #[test]
    fn sigma1_decreases_with_censored_set_size() {
        let l = 5.0;
        let p = ParamChoice::closed_form_43(l, 0.5 / l, 1.0, 0.5, 0.5, 4);
        let s_small = p.lemma1_constants(l, 1).sigma1;
        let s_big = p.lemma1_constants(l, 4).sigma1;
        assert!(s_small > s_big);
    }

    #[test]
    fn too_large_epsilon_violates_lemma1() {
        let l = 5.0;
        let mut p = ParamChoice::closed_form_43(l, 0.5 / l, 1.0, 0.5, 1.0, 4);
        p.epsilon1 *= 10.0;
        assert!(!p.satisfies_lemma1(l, 4));
    }

    #[test]
    fn beta_zero_epsilon_zero_always_admissible() {
        // degenerates to GD: (14) with β = ε₁ = 0 and α ≤ 1/L
        let l = 3.0;
        let p = ParamChoice {
            alpha: 1.0 / l,
            beta: 0.0,
            epsilon1: 0.0,
            eta1: 0.0,
            rho: (1.0, 1.0, 1.0),
        };
        // η₁ = (1−αL)/(2α) = 0 here, so hypothesis holds with equality
        assert!(p.lemma1_constants(l, 9).sigma0 > 0.0);
        assert!(p.lemma1_constants(l, 9).sigma1 >= 0.0);
    }

    #[test]
    fn iteration_complexity_matches_eq59_shape() {
        // doubling the condition number doubles the complexity
        let a = chb_iteration_complexity(10.0, 1.0, 0.0, 1e-6);
        let b = chb_iteration_complexity(20.0, 1.0, 0.0, 1e-6);
        assert!((b / a - 2.0).abs() < 1e-12);
        // tighter ε costs log(1/ε)
        let c = chb_iteration_complexity(10.0, 1.0, 0.0, 1e-12);
        assert!((c / a - 2.0).abs() < 1e-12);
    }

    #[test]
    fn lemma2_predicate_and_bound() {
        assert!(lemma2_applies(0.3, 0.1)); // 0.09 ≤ 0.1
        assert!(!lemma2_applies(0.4, 0.1));
        assert_eq!(lemma2_bound(24), 12);
        assert_eq!(lemma2_bound(25), 13);
    }

    #[test]
    fn lyapunov_tracker_flags_increases() {
        let mut t = LyapunovTracker::new(1.0, 0.0);
        t.record(10.0, 0.0);
        t.record(5.0, 0.1);
        t.record(6.0, 0.0); // increase!
        assert!(t.violation_fraction(1e-12) > 0.0);
        let mut mono = LyapunovTracker::new(1.0, 0.0);
        mono.record(10.0, 0.0);
        mono.record(5.0, 0.0);
        mono.record(2.0, 0.0);
        assert_eq!(mono.violation_fraction(1e-12), 0.0);
    }
}
