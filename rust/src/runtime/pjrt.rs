//! PJRT client wrapper + the PJRT gradient backend.
//!
//! Pattern from /opt/xla-example: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `compile` → `execute`.  HLO
//! *text* is the interchange format (64-bit-id protos from jax ≥ 0.5
//! are rejected by xla_extension 0.5.1 — see aot.py).
//!
//! Per-worker constants (X, y, mask, λ) are uploaded to device
//! buffers once at construction; only θ moves per iteration, and the
//! hot call is `execute_b` over pre-staged buffers.
//!
//! ## Feature gating
//!
//! The real implementation needs the external `xla` crate, which only
//! exists on images built with the xla_extension toolchain.  The
//! default build is hermetic: it compiles a stub whose constructor
//! returns an error, so every caller (CLI `--backend pjrt`, the
//! backends bench, the round-trip tests) degrades gracefully at
//! runtime instead of breaking the build.  Enable the `pjrt` cargo
//! feature **and** add the `xla` dependency on images that ship it to
//! get the real backend.

#[cfg(feature = "pjrt")]
mod real {
    use std::collections::HashMap;
    use std::sync::Arc;

    use anyhow::{bail, Context, Result};

    use crate::coordinator::GradientBackend;
    use crate::data::Shard;

    use super::super::manifest::{ArtifactMeta, Manifest};

    /// Shared PJRT client + compiled-executable cache.
    pub struct PjrtRuntime {
        client: xla::PjRtClient,
        manifest: Manifest,
        /// compile once per artifact, share across the M workers
        cache: HashMap<String, Arc<xla::PjRtLoadedExecutable>>,
    }

    impl PjrtRuntime {
        /// CPU client over the artifacts directory.
        pub fn new(artifact_dir: &std::path::Path) -> Result<Self> {
            let manifest = Manifest::load(artifact_dir)?;
            let client = xla::PjRtClient::cpu().context("PjRtClient::cpu")?;
            Ok(Self { client, manifest, cache: HashMap::new() })
        }

        /// The parsed artifacts manifest.
        pub fn manifest(&self) -> &Manifest {
            &self.manifest
        }

        /// PJRT platform name ("Host" for the CPU client).
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Compile (or fetch from cache) the executable for an artifact.
        pub fn executable(
            &mut self,
            meta: &ArtifactMeta,
        ) -> Result<Arc<xla::PjRtLoadedExecutable>> {
            if let Some(exe) = self.cache.get(&meta.name) {
                return Ok(Arc::clone(exe));
            }
            let path = meta
                .file
                .to_str()
                .context("artifact path is not valid UTF-8")?;
            let proto = xla::HloModuleProto::from_text_file(path)
                .with_context(|| format!("parse HLO text {path}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compile {}", meta.name))?;
            let exe = Arc::new(exe);
            self.cache.insert(meta.name.clone(), Arc::clone(&exe));
            Ok(exe)
        }

        /// Build one worker's backend for (artifact, shard, λ).
        pub fn worker_backend(
            &mut self,
            meta: &ArtifactMeta,
            shard: &Shard,
            lam: f64,
        ) -> Result<PjrtBackend> {
            if shard.x.rows != meta.n_pad || shard.x.cols != meta.d {
                bail!(
                    "shard shape {}x{} does not match artifact {} ({}x{})",
                    shard.x.rows,
                    shard.x.cols,
                    meta.name,
                    meta.n_pad,
                    meta.d
                );
            }
            let exe = self.executable(meta)?;
            // stage the per-worker constants on device, f32
            let xf: Vec<f32> = shard.x.data.iter().map(|&v| v as f32).collect();
            let yf: Vec<f32> = shard.y.iter().map(|&v| v as f32).collect();
            let mut args = Vec::new();
            args.push(
                self.client
                    .buffer_from_host_buffer(&xf, &[meta.n_pad, meta.d], None)?,
            );
            args.push(
                self.client.buffer_from_host_buffer(&yf, &[meta.n_pad], None)?,
            );
            if meta.needs_mask() {
                let mf: Vec<f32> =
                    shard.mask.iter().map(|&v| v as f32).collect();
                args.push(
                    self.client
                        .buffer_from_host_buffer(&mf, &[meta.n_pad], None)?,
                );
            }
            if meta.needs_lam() {
                let lf = [lam as f32];
                args.push(self.client.buffer_from_host_buffer(&lf, &[1], None)?);
            }
            if meta.needs_wscale() {
                // mean-loss data-term scale, matching tasks::NnTask::new
                let ws = [1.0f32 / shard.n_real.max(1) as f32];
                args.push(self.client.buffer_from_host_buffer(&ws, &[1], None)?);
            }
            Ok(PjrtBackend {
                client: self.client.clone(),
                exe,
                const_args: args,
                theta_dim: meta.theta_dim,
                theta_f32: vec![0.0; meta.theta_dim],
                grad_f32: vec![0.0; meta.theta_dim],
            })
        }
    }

    /// GradientBackend that executes the AOT artifact through PJRT.
    pub struct PjrtBackend {
        client: xla::PjRtClient,
        exe: Arc<xla::PjRtLoadedExecutable>,
        /// staged device buffers: x, y [, mask][, lam]
        const_args: Vec<xla::PjRtBuffer>,
        theta_dim: usize,
        /// reusable f32 staging buffers (hot path: no reallocation)
        theta_f32: Vec<f32>,
        grad_f32: Vec<f32>,
    }

    // SAFETY: the PJRT CPU client is thread-safe for buffer upload and
    // execution; the xla crate just doesn't mark its pointer wrappers
    // Send.  Each backend is owned by exactly one worker (possibly on
    // its own thread); the shared executable is immutable after
    // compile.
    unsafe impl Send for PjrtBackend {}

    impl PjrtBackend {
        fn run(&mut self, theta: &[f64]) -> Result<f64> {
            for (dst, &src) in self.theta_f32.iter_mut().zip(theta) {
                *dst = src as f32;
            }
            let theta_buf = self.client.buffer_from_host_buffer(
                &self.theta_f32,
                &[self.theta_dim],
                None,
            )?;
            // argument order: theta, x, y[, mask][, lam] (aot.py)
            let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(5);
            args.push(&theta_buf);
            args.extend(self.const_args.iter());
            let result = self.exe.execute_b(&args)?;
            let replica = &result[0];
            // aot.py lowers with return_tuple=True: one tuple output of
            // (grad, loss); some PJRT versions untuple into two buffers.
            let (grad_lit, loss_lit) = if replica.len() == 2 {
                (replica[0].to_literal_sync()?, replica[1].to_literal_sync()?)
            } else {
                let tup = replica[0].to_literal_sync()?;
                let (g, l) = tup.to_tuple2()?;
                (g, l)
            };
            grad_lit.copy_raw_to(&mut self.grad_f32)?;
            let mut loss = [0f32];
            loss_lit.copy_raw_to(&mut loss)?;
            Ok(loss[0] as f64)
        }
    }

    impl GradientBackend for PjrtBackend {
        fn dim(&self) -> usize {
            self.theta_dim
        }

        fn grad_loss_into(&mut self, theta: &[f64], grad: &mut [f64]) -> f64 {
            let loss = self
                .run(theta)
                .expect("PJRT execution failed on the hot path");
            for (dst, &src) in grad.iter_mut().zip(self.grad_f32.iter()) {
                *dst = src as f64;
            }
            loss
        }
    }
}

#[cfg(feature = "pjrt")]
pub use real::{PjrtBackend, PjrtRuntime};

#[cfg(not(feature = "pjrt"))]
mod stub {
    use anyhow::{bail, Result};

    use crate::coordinator::GradientBackend;
    use crate::data::Shard;

    use super::super::manifest::{ArtifactMeta, Manifest};

    /// Hermetic-build stand-in: construction always fails with a
    /// pointer at the `pjrt` feature, so `--backend pjrt` degrades to
    /// a clear runtime error instead of a broken build.
    pub struct PjrtRuntime {
        manifest: Manifest,
    }

    impl PjrtRuntime {
        /// Always errors: this build has no PJRT support.
        pub fn new(artifact_dir: &std::path::Path) -> Result<Self> {
            bail!(
                "built without PJRT support (artifacts at {} ignored): \
                 on an xla_extension image, add `xla` to \
                 [dependencies] in rust/Cargo.toml and rebuild with \
                 `--features pjrt`",
                artifact_dir.display()
            );
        }

        /// The parsed artifacts manifest (unreachable: `new` errors).
        pub fn manifest(&self) -> &Manifest {
            &self.manifest
        }

        /// PJRT platform name (unreachable: `new` errors).
        pub fn platform(&self) -> String {
            "unavailable".to_string()
        }

        /// Build one worker's backend (unreachable: `new` errors).
        pub fn worker_backend(
            &mut self,
            _meta: &ArtifactMeta,
            _shard: &Shard,
            _lam: f64,
        ) -> Result<PjrtBackend> {
            bail!("built without PJRT support")
        }
    }

    /// Uninhabitable in practice: no [`PjrtRuntime`] value exists to
    /// construct one.
    pub struct PjrtBackend {
        _private: (),
    }

    impl GradientBackend for PjrtBackend {
        fn dim(&self) -> usize {
            unreachable!("stub PjrtBackend cannot be constructed")
        }

        fn grad_loss_into(&mut self, _: &[f64], _: &mut [f64]) -> f64 {
            unreachable!("stub PjrtBackend cannot be constructed")
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use stub::{PjrtBackend, PjrtRuntime};
