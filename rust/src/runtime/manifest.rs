//! artifacts/manifest.json reader (hand-rolled JSON, util::json).

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::tasks::TaskKind;
use crate::util::json::Json;

/// One lowered artifact's metadata.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    /// artifact key ("linreg_synth", …)
    pub name: String,
    /// the task this artifact computes
    pub task: TaskKind,
    /// dataset the shapes were lowered for
    pub dataset: String,
    /// path to the HLO text file
    pub file: PathBuf,
    /// total sample count across workers
    pub n_total: usize,
    /// worker count M the shapes assume
    pub workers: usize,
    /// padded per-worker rows (every worker shares this shape)
    pub n_pad: usize,
    /// feature count
    pub d: usize,
    /// flat parameter dimension
    pub theta_dim: usize,
    /// ordered argument names: theta, x, y[, mask][, lam]
    pub arg_names: Vec<String>,
}

impl ArtifactMeta {
    /// Does the lowered program take a padding mask argument?
    pub fn needs_mask(&self) -> bool {
        self.arg_names.iter().any(|a| a == "mask")
    }

    /// Does the lowered program take a λ argument?
    pub fn needs_lam(&self) -> bool {
        self.arg_names.iter().any(|a| a == "lam")
    }

    /// Does the lowered program take a data-term scale argument?
    pub fn needs_wscale(&self) -> bool {
        self.arg_names.iter().any(|a| a == "wscale")
    }
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// kernel row-tile the shapes were padded to
    pub block_n: usize,
    /// NN hidden width the nn artifacts assume
    pub hidden: usize,
    /// every lowered artifact
    pub artifacts: Vec<ArtifactMeta>,
    /// directory the manifest was loaded from
    pub dir: PathBuf,
}

impl Manifest {
    /// Parse `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {} (run `make artifacts`)", path.display()))?;
        let j = Json::parse(&text).context("parse manifest.json")?;
        let block_n = j.usize_field("block_n")?;
        let hidden = j.usize_field("hidden")?;
        let mut artifacts = Vec::new();
        for a in j
            .get("artifacts")
            .and_then(Json::as_arr)
            .context("manifest: artifacts array")?
        {
            let task_name = a.str_field("task")?;
            let task = TaskKind::parse(task_name)
                .with_context(|| format!("unknown task {task_name:?}"))?;
            let arg_names = a
                .get("args")
                .and_then(Json::as_arr)
                .context("artifact args")?
                .iter()
                .map(|arg| Ok(arg.str_field("name")?.to_string()))
                .collect::<Result<Vec<_>>>()?;
            artifacts.push(ArtifactMeta {
                name: a.str_field("name")?.to_string(),
                task,
                dataset: a.str_field("dataset")?.to_string(),
                file: dir.join(a.str_field("file")?),
                n_total: a.usize_field("n_total")?,
                workers: a.usize_field("workers")?,
                n_pad: a.usize_field("n_pad")?,
                d: a.usize_field("d")?,
                theta_dim: a.usize_field("theta_dim")?,
                arg_names,
            });
        }
        Ok(Manifest { block_n, hidden, artifacts, dir: dir.to_path_buf() })
    }

    /// Find the artifact for (task, dataset).
    pub fn find(&self, task: TaskKind, dataset: &str) -> Result<&ArtifactMeta> {
        self.artifacts
            .iter()
            .find(|a| a.task == task && a.dataset == dataset)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "no artifact for task={} dataset={dataset} \
                     (have: {})",
                    task.name(),
                    self.artifacts
                        .iter()
                        .map(|a| a.name.as_str())
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "block_n": 256, "hidden": 30,
        "artifacts": [{
            "name": "logreg_synth", "task": "logreg", "dataset": "synth",
            "file": "logreg_synth.hlo.txt", "n_total": 450, "workers": 9,
            "n_pad": 50, "d": 50, "theta_dim": 50,
            "args": [{"name": "theta", "shape": [50]},
                     {"name": "x", "shape": [50, 50]},
                     {"name": "y", "shape": [50]},
                     {"name": "mask", "shape": [50]},
                     {"name": "lam", "shape": [1]}],
            "outputs": ["grad", "loss"], "sha256": "x"
        }]
    }"#;

    #[test]
    fn parses_sample_manifest() {
        let dir = std::env::temp_dir().join("chb_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), SAMPLE).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.block_n, 256);
        let a = m.find(TaskKind::LogReg, "synth").unwrap();
        assert_eq!(a.n_pad, 50);
        assert!(a.needs_mask());
        assert!(a.needs_lam());
        assert!(m.find(TaskKind::LinReg, "synth").is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn missing_manifest_is_helpful() {
        let err = Manifest::load(Path::new("/nonexistent/dir")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
