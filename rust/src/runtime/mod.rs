//! PJRT runtime — loads the AOT artifacts (HLO text lowered by
//! python/compile/aot.py) and executes them on the request path.
//!
//! Python never runs here: the rust binary is self-contained once
//! `make artifacts` has produced `artifacts/*.hlo.txt` +
//! `manifest.json`.

pub mod manifest;
pub mod pjrt;

pub use manifest::{ArtifactMeta, Manifest};
pub use pjrt::{PjrtBackend, PjrtRuntime};
