//! Row-major dense matrix with the fused passes the workers need.
//!
//! The two hot kernels mirror the L1 Pallas schedules:
//!   * [`Matrix::gemv`] — y = X·θ        (row-streaming, like Xθ in VMEM)
//!   * [`Matrix::gemv_t_into`] — g = Xᵀ·r (accumulating, like the grad tile)
//! plus a cache-blocked [`Matrix::matmul`] used by tests and the
//! smoothness estimator.

use super::{axpy, dot};

/// Row-major (n × d) matrix of f64.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    /// row count n
    pub rows: usize,
    /// column count d
    pub cols: usize,
    /// row-major backing storage (`rows * cols` entries)
    pub data: Vec<f64>,
}

impl Matrix {
    /// All-zero (rows × cols) matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Build from row vectors (must all share one length).
    pub fn from_rows(rows: Vec<Vec<f64>>) -> Self {
        let r = rows.len();
        let c = rows.first().map_or(0, |x| x.len());
        let mut data = Vec::with_capacity(r * c);
        for row in &rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Self { rows: r, cols: c, data }
    }

    /// Wrap a row-major flat buffer (length must be rows·cols).
    pub fn from_flat(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Self { rows, cols, data }
    }

    /// Row i as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Row i as a mutable slice.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Entry (i, j).
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.cols + j]
    }

    /// Set entry (i, j) to `v`.
    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.data[i * self.cols + j] = v;
    }

    /// out ← X·θ  (out.len() == rows)
    pub fn gemv(&self, theta: &[f64], out: &mut [f64]) {
        assert_eq!(theta.len(), self.cols);
        assert_eq!(out.len(), self.rows);
        for i in 0..self.rows {
            out[i] = dot(self.row(i), theta);
        }
    }

    /// g ← Xᵀ·r  (g.len() == cols). Overwrites g.
    ///
    /// Row-streaming accumulation: one pass over X in memory order,
    /// exactly the access pattern of the Pallas gradient kernels.
    pub fn gemv_t_into(&self, r: &[f64], g: &mut [f64]) {
        assert_eq!(r.len(), self.rows);
        assert_eq!(g.len(), self.cols);
        g.fill(0.0);
        for i in 0..self.rows {
            let ri = r[i];
            if ri == 0.0 {
                continue; // padded / masked rows cost nothing
            }
            let row = self.row(i);
            for j in 0..self.cols {
                g[j] += ri * row[j];
            }
        }
    }

    /// One generic body behind [`Matrix::fused_residual_grad`] and
    /// [`Matrix::fused_residual_grad_rows`]: monomorphized over the
    /// row iterator, so the full-sweep and row-subset instantiations
    /// share the identical per-row schedule (dot, residual, guarded
    /// rank-1 accumulate, ½Σr² loss) — the "over `0..n` bit-identical"
    /// invariant holds by construction, not by keeping two loop bodies
    /// in lockstep.  No batch-mode branching inside the loop.
    fn fused_residual_grad_impl<I>(
        &self,
        theta: &[f64],
        y: &[f64],
        rows: I,
        resid: &mut [f64],
        grad: &mut [f64],
    ) -> f64
    where
        I: Iterator<Item = usize>,
    {
        assert_eq!(theta.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        assert_eq!(resid.len(), self.rows);
        assert_eq!(grad.len(), self.cols);
        let mut loss = 0.0;
        for i in rows {
            let row = self.row(i);
            let r = dot(row, theta) - y[i];
            resid[i] = r;
            loss += r * r;
            if r != 0.0 {
                for j in 0..self.cols {
                    grad[j] += r * row[j];
                }
            }
        }
        0.5 * loss
    }

    /// Fused residual-gradient pass (the rust mirror of the L1 Pallas
    /// schedule): in ONE sweep over X computes
    ///   r_i = x_iᵀθ − y_i   (written to `resid`)
    ///   g  += Σ_i r_i·x_i   (`grad` must be zeroed by the caller)
    /// and returns ½Σ r_i².  Halves the memory traffic of the naive
    /// gemv + gemv_t pair — X is DRAM-resident at MNIST shapes, so
    /// this is ~2× end-to-end (EXPERIMENTS.md §Perf).
    pub fn fused_residual_grad(
        &self,
        theta: &[f64],
        y: &[f64],
        resid: &mut [f64],
        grad: &mut [f64],
    ) -> f64 {
        self.fused_residual_grad_impl(theta, y, 0..self.rows, resid, grad)
    }

    /// Row-subset variant of [`Matrix::fused_residual_grad`], the
    /// minibatch kernel: the identical per-row schedule (one shared
    /// generic body), but visiting only the rows named by `rows`, in
    /// slice order.  `resid` is indexed by the *absolute* row index
    /// (same layout as the full pass), so callers can reuse one n-row
    /// buffer for any batch.  With `rows == 0..n` the result is
    /// bit-identical to [`Matrix::fused_residual_grad`] — pinned by a
    /// test below and by `tests/batch_equivalence.rs` end to end.
    pub fn fused_residual_grad_rows(
        &self,
        theta: &[f64],
        y: &[f64],
        rows: &[u32],
        resid: &mut [f64],
        grad: &mut [f64],
    ) -> f64 {
        self.fused_residual_grad_impl(
            theta,
            y,
            rows.iter().map(|&i| i as usize),
            resid,
            grad,
        )
    }

    /// Fused coefficient-gradient pass — the logistic/lasso sibling of
    /// [`Matrix::fused_residual_grad`]: in ONE sweep over X computes
    ///   z_i = x_iᵀθ
    ///   (ℓ_i, c_i) = coeff(i, z_i)   (caller-supplied per-row map)
    ///   g  += Σ_i c_i·x_i            (`grad` must be zeroed by the caller)
    /// and returns Σ ℓ_i.  Rows with `mask[i] == 0` are skipped before
    /// the dot product, so padding rows cost nothing and contribute
    /// nothing (the loss map never sees them).  Row order and the
    /// `c_i != 0` accumulation guard match [`Matrix::gemv_t_into`]
    /// exactly, so traces stay bit-identical to the unfused
    /// gemv + per-row-map + gemv_t composition.
    pub fn fused_coeff_grad<F>(
        &self,
        theta: &[f64],
        mask: &[f64],
        coeff: F,
        grad: &mut [f64],
    ) -> f64
    where
        F: FnMut(usize, f64) -> (f64, f64),
    {
        self.fused_coeff_grad_impl(theta, mask, 0..self.rows, coeff, grad)
    }

    /// Row-subset variant of [`Matrix::fused_coeff_grad`], the
    /// minibatch kernel: identical per-row schedule (one shared
    /// generic body) over only the rows named by `rows`, in slice
    /// order.  With `rows == 0..n` results are bit-identical to the
    /// full sweep.
    pub fn fused_coeff_grad_rows<F>(
        &self,
        theta: &[f64],
        mask: &[f64],
        rows: &[u32],
        coeff: F,
        grad: &mut [f64],
    ) -> f64
    where
        F: FnMut(usize, f64) -> (f64, f64),
    {
        self.fused_coeff_grad_impl(
            theta,
            mask,
            rows.iter().map(|&i| i as usize),
            coeff,
            grad,
        )
    }

    /// One generic body behind [`Matrix::fused_coeff_grad`] and
    /// [`Matrix::fused_coeff_grad_rows`] (see
    /// [`Matrix::fused_residual_grad_impl`] for the rationale): mask
    /// skip, dot, caller-supplied (ℓ, c) map, `c != 0` guarded rank-1
    /// accumulate — identical schedule for both instantiations.
    fn fused_coeff_grad_impl<I, F>(
        &self,
        theta: &[f64],
        mask: &[f64],
        rows: I,
        mut coeff: F,
        grad: &mut [f64],
    ) -> f64
    where
        I: Iterator<Item = usize>,
        F: FnMut(usize, f64) -> (f64, f64),
    {
        assert_eq!(theta.len(), self.cols);
        assert_eq!(mask.len(), self.rows);
        assert_eq!(grad.len(), self.cols);
        let mut loss = 0.0;
        for i in rows {
            if mask[i] == 0.0 {
                continue;
            }
            let row = self.row(i);
            let z = dot(row, theta);
            let (li, ci) = coeff(i, z);
            loss += li;
            if ci != 0.0 {
                // shared rank-1 kernel, same per-element op order as
                // the hand-rolled loop
                axpy(ci, row, grad);
            }
        }
        loss
    }

    /// Cache-blocked C = A·B (used off the hot path).
    pub fn matmul(&self, b: &Matrix) -> Matrix {
        assert_eq!(self.cols, b.rows);
        const BLK: usize = 64;
        let mut c = Matrix::zeros(self.rows, b.cols);
        for kk in (0..self.cols).step_by(BLK) {
            let kend = (kk + BLK).min(self.cols);
            for i in 0..self.rows {
                let arow = self.row(i);
                for k in kk..kend {
                    let aik = arow[k];
                    if aik == 0.0 {
                        continue;
                    }
                    let brow = b.row(k);
                    let crow = c.row_mut(i);
                    for j in 0..b.cols {
                        crow[j] += aik * brow[j];
                    }
                }
            }
        }
        c
    }

    /// Xᵀ as a new matrix (off the hot path).
    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t.set(j, i, self.get(i, j));
            }
        }
        t
    }

    /// Frobenius-scale every entry.
    pub fn scale(&mut self, a: f64) {
        for v in &mut self.data {
            *v *= a;
        }
    }

    /// Take the first `k` columns (the paper's min-feature truncation).
    pub fn truncate_cols(&self, k: usize) -> Matrix {
        assert!(k <= self.cols);
        let mut m = Matrix::zeros(self.rows, k);
        for i in 0..self.rows {
            m.row_mut(i).copy_from_slice(&self.row(i)[..k]);
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Matrix {
        Matrix::from_rows(vec![
            vec![1.0, 2.0],
            vec![3.0, 4.0],
            vec![5.0, 6.0],
        ])
    }

    #[test]
    fn gemv_basic() {
        let m = small();
        let mut out = vec![0.0; 3];
        m.gemv(&[1.0, 1.0], &mut out);
        assert_eq!(out, vec![3.0, 7.0, 11.0]);
    }

    #[test]
    fn gemv_t_matches_transpose_gemv() {
        let m = small();
        let r = vec![1.0, -1.0, 2.0];
        let mut g = vec![0.0; 2];
        m.gemv_t_into(&r, &mut g);
        let t = m.transpose();
        let mut expect = vec![0.0; 2];
        t.gemv(&r, &mut expect);
        assert_eq!(g, expect);
    }

    #[test]
    fn fused_residual_grad_matches_two_pass_bitwise() {
        let m = small();
        let theta = [0.5, -1.25];
        let y = [1.0, -2.0, 0.75];
        // two-pass reference: gemv, subtract, gemv_t
        let mut resid = vec![0.0; 3];
        m.gemv(&theta, &mut resid);
        for (r, yv) in resid.iter_mut().zip(&y) {
            *r -= yv;
        }
        let mut g_ref = vec![0.0; 2];
        m.gemv_t_into(&resid, &mut g_ref);
        // fused pass
        let mut r2 = vec![0.0; 3];
        let mut g = vec![0.0; 2];
        let loss = m.fused_residual_grad(&theta, &y, &mut r2, &mut g);
        for (a, b) in resid.iter().zip(&r2) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in g_ref.iter().zip(&g) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        let want: f64 = resid.iter().map(|r| r * r).sum();
        assert!((loss - 0.5 * want).abs() < 1e-15);
    }

    #[test]
    fn fused_coeff_grad_matches_unfused_composition() {
        let m = small();
        let theta = [0.3, 0.7];
        let mask = [1.0, 0.0, 1.0];
        // reference: dot per unmasked row, c_i = 2·z_i + 1, ℓ_i = z_i²
        let mut g_ref = vec![0.0; 2];
        let mut loss_ref = 0.0;
        for i in [0usize, 2] {
            let z = super::dot(m.row(i), &theta);
            loss_ref += z * z;
            let c = 2.0 * z + 1.0;
            for j in 0..2 {
                g_ref[j] += c * m.row(i)[j];
            }
        }
        let mut g = vec![0.0; 2];
        let loss =
            m.fused_coeff_grad(&theta, &mask, |_, z| (z * z, 2.0 * z + 1.0), &mut g);
        assert_eq!(loss.to_bits(), loss_ref.to_bits());
        for (a, b) in g_ref.iter().zip(&g) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn fused_coeff_grad_skips_masked_rows_entirely() {
        let m = small();
        let mut seen = Vec::new();
        let mut g = vec![0.0; 2];
        let loss = m.fused_coeff_grad(
            &[1.0, 1.0],
            &[0.0, 1.0, 0.0],
            |i, z| {
                seen.push((i, z));
                (1.0, 0.0)
            },
            &mut g,
        );
        assert_eq!(seen, vec![(1, 7.0)]);
        assert_eq!(loss, 1.0);
        assert_eq!(g, vec![0.0, 0.0]); // c = 0 ⇒ no accumulation
    }

    #[test]
    fn fused_residual_grad_rows_all_rows_is_bitwise_full_pass() {
        let m = small();
        let theta = [0.5, -1.25];
        let y = [1.0, -2.0, 0.75];
        let mut r_full = vec![0.0; 3];
        let mut g_full = vec![0.0; 2];
        let l_full = m.fused_residual_grad(&theta, &y, &mut r_full, &mut g_full);
        let rows: Vec<u32> = (0..3).collect();
        let mut r_sub = vec![0.0; 3];
        let mut g_sub = vec![0.0; 2];
        let l_sub =
            m.fused_residual_grad_rows(&theta, &y, &rows, &mut r_sub, &mut g_sub);
        assert_eq!(l_full.to_bits(), l_sub.to_bits());
        for (a, b) in g_full.iter().zip(&g_sub) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in r_full.iter().zip(&r_sub) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn fused_residual_grad_rows_subset_matches_manual_sum() {
        let m = small();
        let theta = [1.0, 0.5];
        let y = [0.0, 1.0, -1.0];
        let rows = [2u32, 0];
        let mut resid = vec![0.0; 3];
        let mut g = vec![0.0; 2];
        let loss = m.fused_residual_grad_rows(&theta, &y, &rows, &mut resid, &mut g);
        // manual: visit rows 2 then 0
        let mut g_ref = vec![0.0; 2];
        let mut l_ref = 0.0;
        for &i in &[2usize, 0] {
            let r = super::dot(m.row(i), &theta) - y[i];
            l_ref += r * r;
            for j in 0..2 {
                g_ref[j] += r * m.row(i)[j];
            }
        }
        assert_eq!(loss.to_bits(), (0.5 * l_ref).to_bits());
        for (a, b) in g.iter().zip(&g_ref) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // untouched row's resid slot stays zero
        assert_eq!(resid[1], 0.0);
    }

    #[test]
    fn fused_coeff_grad_rows_all_rows_is_bitwise_full_pass() {
        let m = small();
        let theta = [0.3, 0.7];
        let mask = [1.0, 0.0, 1.0];
        let mut g_full = vec![0.0; 2];
        let l_full =
            m.fused_coeff_grad(&theta, &mask, |_, z| (z * z, 2.0 * z + 1.0), &mut g_full);
        let rows: Vec<u32> = (0..3).collect();
        let mut g_sub = vec![0.0; 2];
        let l_sub = m.fused_coeff_grad_rows(
            &theta,
            &mask,
            &rows,
            |_, z| (z * z, 2.0 * z + 1.0),
            &mut g_sub,
        );
        assert_eq!(l_full.to_bits(), l_sub.to_bits());
        for (a, b) in g_full.iter().zip(&g_sub) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn matmul_matches_naive() {
        let a = Matrix::from_rows(vec![vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]]);
        let b = Matrix::from_rows(vec![
            vec![7.0, 8.0],
            vec![9.0, 10.0],
            vec![11.0, 12.0],
        ]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![58.0, 64.0, 139.0, 154.0]);
    }

    #[test]
    fn matmul_blocked_large() {
        // exercise the BLK-boundary logic with cols > BLK
        let n = 70;
        let mut a = Matrix::zeros(3, n);
        let mut b = Matrix::zeros(n, 2);
        for k in 0..n {
            a.set(0, k, 1.0);
            a.set(1, k, k as f64);
            b.set(k, 0, 1.0);
            b.set(k, 1, 2.0);
        }
        let c = a.matmul(&b);
        assert_eq!(c.get(0, 0), n as f64);
        assert_eq!(c.get(0, 1), 2.0 * n as f64);
        let sumk: f64 = (0..n).map(|k| k as f64).sum();
        assert_eq!(c.get(1, 0), sumk);
    }

    #[test]
    fn truncate_cols_keeps_prefix() {
        let m = small().truncate_cols(1);
        assert_eq!(m.cols, 1);
        assert_eq!(m.data, vec![1.0, 3.0, 5.0]);
    }
}
