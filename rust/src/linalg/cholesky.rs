//! Cholesky factorization / SPD solve — used by the f* solvers
//! (normal equations for linear regression, Newton steps for
//! logistic regression).  Off the hot path.

use anyhow::{bail, Result};

use super::Matrix;

/// Lower-triangular Cholesky factor of an SPD matrix (in place copy).
pub struct Cholesky {
    l: Matrix,
}

impl Cholesky {
    /// Factor A = L·Lᵀ.  `ridge` is added to the diagonal first
    /// (regularization / numerical floor).
    pub fn factor(a: &Matrix, ridge: f64) -> Result<Cholesky> {
        if a.rows != a.cols {
            bail!("cholesky: non-square {}x{}", a.rows, a.cols);
        }
        let n = a.rows;
        let mut l = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let mut sum = a.get(i, j) + if i == j { ridge } else { 0.0 };
                for k in 0..j {
                    sum -= l.get(i, k) * l.get(j, k);
                }
                if i == j {
                    if sum <= 0.0 {
                        bail!(
                            "cholesky: matrix not positive definite \
                             (pivot {i}: {sum:.3e}); increase ridge"
                        );
                    }
                    l.set(i, j, sum.sqrt());
                } else {
                    l.set(i, j, sum / l.get(j, j));
                }
            }
        }
        Ok(Cholesky { l })
    }

    /// Solve A·x = b via forward/back substitution.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let n = self.l.rows;
        assert_eq!(b.len(), n);
        // L·z = b
        let mut z = vec![0.0; n];
        for i in 0..n {
            let mut sum = b[i];
            for k in 0..i {
                sum -= self.l.get(i, k) * z[k];
            }
            z[i] = sum / self.l.get(i, i);
        }
        // Lᵀ·x = z
        let mut x = vec![0.0; n];
        for i in (0..n).rev() {
            let mut sum = z[i];
            for k in i + 1..n {
                sum -= self.l.get(k, i) * x[k];
            }
            x[i] = sum / self.l.get(i, i);
        }
        x
    }
}

/// Gram matrix Σ_s X_sᵀX_s over shards (d × d).
pub fn gram(shards: &[&Matrix]) -> Matrix {
    let d = shards.first().map_or(0, |x| x.cols);
    let mut g = Matrix::zeros(d, d);
    for x in shards {
        assert_eq!(x.cols, d);
        for i in 0..x.rows {
            let row = x.row(i);
            for a in 0..d {
                let ra = row[a];
                if ra == 0.0 {
                    continue;
                }
                for b in 0..d {
                    g.data[a * d + b] += ra * row[b];
                }
            }
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factors_and_solves_spd_system() {
        // A = [[4,2],[2,3]], b = [1, 2] → x = [−1/8, 3/4]
        let a = Matrix::from_rows(vec![vec![4.0, 2.0], vec![2.0, 3.0]]);
        let ch = Cholesky::factor(&a, 0.0).unwrap();
        let x = ch.solve(&[1.0, 2.0]);
        assert!((x[0] - (-0.125)).abs() < 1e-12);
        assert!((x[1] - 0.75).abs() < 1e-12);
    }

    #[test]
    fn rejects_indefinite() {
        let a = Matrix::from_rows(vec![vec![1.0, 2.0], vec![2.0, 1.0]]);
        assert!(Cholesky::factor(&a, 0.0).is_err());
        // but a big enough ridge fixes it
        assert!(Cholesky::factor(&a, 2.0).is_ok());
    }

    #[test]
    fn gram_matches_naive() {
        let x = Matrix::from_rows(vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
        let g = gram(&[&x]);
        // XᵀX = [[10, 14], [14, 20]]
        assert_eq!(g.data, vec![10.0, 14.0, 14.0, 20.0]);
        let g2 = gram(&[&x, &x]);
        assert_eq!(g2.get(0, 0), 20.0);
    }

    #[test]
    fn random_spd_round_trip() {
        use crate::rng::Xoshiro256;
        let mut rng = Xoshiro256::new(31);
        let n = 12;
        let mut b_mat = Matrix::zeros(n, n);
        for v in &mut b_mat.data {
            *v = rng.next_gaussian();
        }
        let a = gram(&[&b_mat]); // BᵀB is PSD; ridge makes it PD
        let ch = Cholesky::factor(&a, 1e-6).unwrap();
        let x_true: Vec<f64> = rng.gaussian_vec(n);
        let mut b = vec![0.0; n];
        // b = A x_true (+ ridge·x_true to match the factored system)
        for i in 0..n {
            b[i] = (0..n).map(|j| a.get(i, j) * x_true[j]).sum::<f64>()
                + 1e-6 * x_true[i];
        }
        let x = ch.solve(&b);
        for i in 0..n {
            assert!((x[i] - x_true[i]).abs() < 1e-6, "{i}");
        }
    }
}
