//! Runtime-dispatched SIMD kernels for the four hottest loops.
//!
//! The fused gradient kernels ([`crate::linalg::Matrix`]), the server
//! fold primitives ([`crate::linalg::axpy`] /
//! [`crate::linalg::axpy_sparse`]), and the packed-codec
//! quantize/convert loops ([`crate::compress::packed`]) all route
//! through one [`SimdKernels`] table, selected **once** per process:
//!
//! * `x86_64` — AVX2 (256-bit, 4 × f64 lanes) when
//!   `is_x86_feature_detected!("avx2")` says so.  AVX-512-capable
//!   hosts also report AVX2 and run this backend: the 512-bit f64
//!   intrinsics were stabilized after our 1.73 MSRV, so a dedicated
//!   `Backend::Avx512` slot is left to a future MSRV bump — the trait
//!   and dispatch below are already shaped for it.
//! * `aarch64` — NEON (128-bit, 2 × f64 lanes × 2 accumulators; NEON
//!   is architecturally mandatory, no runtime probe needed).
//! * everywhere — the portable scalar reference, also forced by
//!   `CHB_FORCE_SCALAR=1` in the environment (the CI fallback leg).
//!
//! **The load-bearing invariant: every backend is bit-identical to
//! scalar.**  The scalar [`scalar::dot`] is 4-way unrolled with a
//! fixed `(s0+s1)+(s2+s3)` association order, and the vector backends
//! reproduce exactly that shape (one lane per unroll slot, separate
//! multiply and add — never FMA-contracted, which intrinsics forbid),
//! so switching backends never perturbs a pinned trace.
//! `tests/simd_equivalence.rs` property-pins every available backend
//! against scalar on random shapes and alignments.

use std::sync::atomic::{AtomicU8, Ordering};

#[cfg(target_arch = "x86_64")]
pub mod avx2;
#[cfg(target_arch = "aarch64")]
pub mod neon;

/// Environment variable that forces the scalar backend when set to
/// `1` (or `true`) — the CI matrix leg that keeps the fallback tested.
pub const FORCE_SCALAR_ENV: &str = "CHB_FORCE_SCALAR";

/// One backend's kernel table.
///
/// Default methods delegate to the scalar reference, so a backend
/// overrides exactly the loops it accelerates and everything else
/// stays on the (always-correct) fallback.  All implementations must
/// be bit-identical to [`scalar`] — the dispatch may legally switch
/// backend mid-process (benches do), so any numeric divergence would
/// break trace pinning.
pub trait SimdKernels: Send + Sync {
    /// Backend label for logs and bench rows.
    fn name(&self) -> &'static str;

    /// x·y in the scalar reference's fixed association order.
    fn dot(&self, x: &[f64], y: &[f64]) -> f64 {
        scalar::dot(x, y)
    }

    /// y ← y + a·x (dense fold / rank-1 accumulate).
    fn axpy(&self, a: f64, x: &[f64], y: &mut [f64]) {
        scalar::axpy(a, x, y)
    }

    /// y[idx[j]] ← y[idx[j]] + a·val[j] (sparse fold).
    ///
    /// Stays scalar on every backend: a gather/scatter over
    /// potentially duplicate indices needs conflict detection to
    /// vectorize safely, and payload nnz is small by construction —
    /// the bench row exists to document the parity.
    fn axpy_sparse(&self, a: f64, idx: &[u32], val: &[f64], y: &mut [f64]) {
        scalar::axpy_sparse(a, idx, val, y)
    }

    /// dst[i] ← bits of `src[i] as f32` (fp32 codec pack).
    fn cvt_f64_to_f32_bits(&self, src: &[f64], dst: &mut [u32]) {
        scalar::cvt_f64_to_f32_bits(src, dst)
    }

    /// y[i] ← y[i] + a·f64::from(f32::from_bits(bits[i])) — the fp32
    /// codec's decode-and-fold in one pass.
    fn cvt_f32_bits_axpy(&self, a: f64, bits: &[u32], y: &mut [f64]) {
        scalar::cvt_f32_bits_axpy(a, bits, y)
    }

    /// out[i] ← clamp(round_half_away(src[i]·inv_scale), ±levels)
    /// (uniform-quantizer pack front half; see
    /// [`scalar::quantize_one`] for the exact op sequence backends
    /// must reproduce).
    fn quantize_clamped(
        &self,
        src: &[f64],
        inv_scale: f64,
        levels: f64,
        out: &mut [f64],
    ) {
        scalar::quantize_clamped(src, inv_scale, levels, out)
    }
}

/// The portable scalar reference kernels — always available, and the
/// semantics every vector backend is pinned against.
pub mod scalar {
    /// x·y, 4-way unrolled with the fixed `(s0+s1)+(s2+s3)`
    /// association order (keeps the FMA ports busy *and* makes the
    /// result deterministic and backend-independent).
    #[inline]
    pub fn dot(x: &[f64], y: &[f64]) -> f64 {
        debug_assert_eq!(x.len(), y.len());
        let n = x.len();
        let chunks = n / 4;
        let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
        for i in 0..chunks {
            let b = i * 4;
            s0 += x[b] * y[b];
            s1 += x[b + 1] * y[b + 1];
            s2 += x[b + 2] * y[b + 2];
            s3 += x[b + 3] * y[b + 3];
        }
        let mut s = (s0 + s1) + (s2 + s3);
        for i in chunks * 4..n {
            s += x[i] * y[i];
        }
        s
    }

    /// y ← y + a·x (element-wise: separate multiply then add, which
    /// any lane width reproduces exactly).
    #[inline]
    pub fn axpy(a: f64, x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(x.len(), y.len());
        for i in 0..x.len() {
            y[i] += a * x[i];
        }
    }

    /// y[idx[j]] ← y[idx[j]] + a·val[j] — each stored coordinate
    /// touches `y` exactly once, in index order.
    #[inline]
    pub fn axpy_sparse(a: f64, idx: &[u32], val: &[f64], y: &mut [f64]) {
        debug_assert_eq!(idx.len(), val.len());
        for (&i, &v) in idx.iter().zip(val) {
            y[i as usize] += a * v;
        }
    }

    /// dst[i] ← (src[i] as f32).to_bits() — IEEE round-to-nearest-even
    /// narrowing, exactly what the hardware converts do.
    #[inline]
    pub fn cvt_f64_to_f32_bits(src: &[f64], dst: &mut [u32]) {
        debug_assert_eq!(src.len(), dst.len());
        for (d, &v) in dst.iter_mut().zip(src) {
            *d = (v as f32).to_bits();
        }
    }

    /// y[i] += a · (f32::from_bits(bits[i]) as f64) — widening is
    /// exact, so this matches the vector converts bit for bit.
    #[inline]
    pub fn cvt_f32_bits_axpy(a: f64, bits: &[u32], y: &mut [f64]) {
        debug_assert_eq!(bits.len(), y.len());
        for (v, &b) in y.iter_mut().zip(bits) {
            *v += a * f64::from(f32::from_bits(b));
        }
    }

    /// One quantizer step: t = v·inv_scale, round half away from zero
    /// via `trunc(t + copysign(0.5, t))`, clamp to ±levels.
    ///
    /// The clamp is written with the x86 `maxpd`/`minpd` operand
    /// semantics (NaN and ties resolve to the *second* operand) so
    /// the vector backends are bit-identical, NaN propagation
    /// included.  The add-half-then-truncate rounding differs from
    /// `f64::round` only on the one double just below 0.5 — an
    /// off-by-one-level knife edge a lossy quantizer doesn't care
    /// about, in exchange for an exactly vectorizable op sequence.
    #[inline]
    pub fn quantize_one(v: f64, inv_scale: f64, levels: f64) -> f64 {
        let t = v * inv_scale;
        let r = (t + 0.5f64.copysign(t)).trunc();
        let m = if -levels > r { -levels } else { r };
        if levels < m {
            levels
        } else {
            m
        }
    }

    /// out[i] ← [`quantize_one`] (src[i]).
    #[inline]
    pub fn quantize_clamped(
        src: &[f64],
        inv_scale: f64,
        levels: f64,
        out: &mut [f64],
    ) {
        debug_assert_eq!(src.len(), out.len());
        for (o, &v) in out.iter_mut().zip(src) {
            *o = quantize_one(v, inv_scale, levels);
        }
    }
}

/// The scalar backend as a [`SimdKernels`] table.
pub struct ScalarKernels;

impl SimdKernels for ScalarKernels {
    fn name(&self) -> &'static str {
        "scalar"
    }
}

static SCALAR: ScalarKernels = ScalarKernels;
#[cfg(target_arch = "x86_64")]
static AVX2: avx2::Avx2Kernels = avx2::Avx2Kernels;
#[cfg(target_arch = "aarch64")]
static NEON: neon::NeonKernels = neon::NeonKernels;

/// A selectable kernel backend.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// portable scalar reference (always available)
    Scalar,
    /// 256-bit AVX2 (x86_64, runtime-detected)
    Avx2,
    /// 128-bit NEON (aarch64 baseline)
    Neon,
}

impl Backend {
    /// Stable label ("scalar" / "avx2" / "neon").
    pub fn label(self) -> &'static str {
        match self {
            Backend::Scalar => "scalar",
            Backend::Avx2 => "avx2",
            Backend::Neon => "neon",
        }
    }

    /// This backend's kernel table.  Selecting a backend that is not
    /// compiled for the current architecture falls back to scalar
    /// (`available()` never lists such a backend).
    pub fn kernels(self) -> &'static dyn SimdKernels {
        match self {
            Backend::Scalar => &SCALAR,
            #[cfg(target_arch = "x86_64")]
            Backend::Avx2 => &AVX2,
            #[cfg(target_arch = "aarch64")]
            Backend::Neon => &NEON,
            _ => &SCALAR,
        }
    }

    fn index(self) -> u8 {
        match self {
            Backend::Scalar => 0,
            Backend::Avx2 => 1,
            Backend::Neon => 2,
        }
    }

    fn from_index(i: u8) -> Backend {
        match i {
            1 => Backend::Avx2,
            2 => Backend::Neon,
            _ => Backend::Scalar,
        }
    }
}

/// Backends usable on this machine, scalar first.
pub fn available() -> Vec<Backend> {
    let mut v = vec![Backend::Scalar];
    #[cfg(target_arch = "x86_64")]
    {
        if std::is_x86_feature_detected!("avx2") {
            v.push(Backend::Avx2);
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        v.push(Backend::Neon);
    }
    v
}

const SEL_UNSET: u8 = u8::MAX;
static SELECTED: AtomicU8 = AtomicU8::new(SEL_UNSET);

fn detect() -> Backend {
    let forced = match std::env::var(FORCE_SCALAR_ENV) {
        Ok(v) => v == "1" || v.eq_ignore_ascii_case("true"),
        Err(_) => false,
    };
    if forced {
        Backend::Scalar
    } else {
        detect_arch()
    }
}

#[cfg(target_arch = "x86_64")]
fn detect_arch() -> Backend {
    if std::is_x86_feature_detected!("avx2") {
        Backend::Avx2
    } else {
        Backend::Scalar
    }
}

#[cfg(target_arch = "aarch64")]
fn detect_arch() -> Backend {
    Backend::Neon
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn detect_arch() -> Backend {
    Backend::Scalar
}

/// The active backend (feature detection + `CHB_FORCE_SCALAR`
/// override, computed once on first use).
pub fn active() -> Backend {
    let i = SELECTED.load(Ordering::Relaxed);
    if i != SEL_UNSET {
        return Backend::from_index(i);
    }
    let b = detect();
    SELECTED.store(b.index(), Ordering::Relaxed);
    b
}

/// The active backend's kernel table — what [`crate::linalg::dot`]
/// and friends dispatch through.
#[inline]
pub fn kernels() -> &'static dyn SimdKernels {
    active().kernels()
}

/// Override the active backend (benches and the cross-backend
/// equivalence test; both single-threaded).  Safe in the numeric
/// sense regardless — every backend is pinned bit-identical — but
/// concurrent benchmark timing would be meaningless, so keep this out
/// of parallel code.
pub fn set_active(b: Backend) {
    SELECTED.store(b.index(), Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = crate::rng::Xoshiro256::new(seed);
        (0..n).map(|_| rng.next_gaussian()).collect()
    }

    #[test]
    fn scalar_backend_is_always_available_and_first() {
        let av = available();
        assert_eq!(av[0], Backend::Scalar);
        assert!(av.contains(&active()) || active() == Backend::Scalar);
    }

    #[test]
    fn every_available_backend_matches_scalar_bitwise() {
        for &b in &available() {
            let k = b.kernels();
            for n in [0usize, 1, 3, 4, 7, 16, 33, 257] {
                let x = mk(n, 0x51AD + n as u64);
                let y = mk(n, 0xB0B + n as u64);
                assert_eq!(
                    k.dot(&x, &y).to_bits(),
                    scalar::dot(&x, &y).to_bits(),
                    "dot {} n={n}",
                    b.label()
                );
                let mut ya = y.clone();
                let mut yb = y.clone();
                k.axpy(0.37, &x, &mut ya);
                scalar::axpy(0.37, &x, &mut yb);
                for (a, c) in ya.iter().zip(&yb) {
                    assert_eq!(a.to_bits(), c.to_bits(), "axpy {}", b.label());
                }
                let mut da = vec![0u32; n];
                let mut db = vec![0u32; n];
                k.cvt_f64_to_f32_bits(&x, &mut da);
                scalar::cvt_f64_to_f32_bits(&x, &mut db);
                assert_eq!(da, db, "cvt pack {}", b.label());
                let mut fa = y.clone();
                let mut fb = y.clone();
                k.cvt_f32_bits_axpy(1.0, &da, &mut fa);
                scalar::cvt_f32_bits_axpy(1.0, &db, &mut fb);
                for (a, c) in fa.iter().zip(&fb) {
                    assert_eq!(
                        a.to_bits(),
                        c.to_bits(),
                        "cvt fold {}",
                        b.label()
                    );
                }
                let mut qa = vec![0.0; n];
                let mut qb = vec![0.0; n];
                k.quantize_clamped(&x, 42.5, 127.0, &mut qa);
                scalar::quantize_clamped(&x, 42.5, 127.0, &mut qb);
                for (a, c) in qa.iter().zip(&qb) {
                    assert_eq!(a.to_bits(), c.to_bits(), "quant {}", b.label());
                }
            }
        }
    }

    #[test]
    fn quantize_one_rounds_half_away_and_clamps() {
        assert_eq!(scalar::quantize_one(2.5, 1.0, 7.0), 3.0);
        assert_eq!(scalar::quantize_one(-2.5, 1.0, 7.0), -3.0);
        assert_eq!(scalar::quantize_one(100.0, 1.0, 7.0), 7.0);
        assert_eq!(scalar::quantize_one(-100.0, 1.0, 7.0), -7.0);
        assert_eq!(scalar::quantize_one(0.0, 2.0, 7.0), 0.0);
        // NaN propagates (and later packs as level 0)
        assert!(scalar::quantize_one(f64::NAN, 1.0, 7.0).is_nan());
    }
}
