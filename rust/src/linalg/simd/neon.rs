//! NEON (128-bit, 2 × f64) kernel backend — aarch64 only, where NEON
//! is architecturally mandatory (no runtime probe needed).
//!
//! Two 2-lane accumulators stand in for the scalar reference's four
//! unroll slots — `acc01` carries (s0, s1), `acc23` carries (s2, s3)
//! — and the reduction is the same `(s0+s1)+(s2+s3)`, so results are
//! bit-identical to [`super::scalar`].  The convert/quantize loops
//! stay on the scalar fallback: they auto-vectorize well on aarch64
//! and the reduction-order-sensitive kernels are the ones that need
//! hand pinning.

use core::arch::aarch64::*;

use super::SimdKernels;

/// The NEON kernel table.
pub struct NeonKernels;

impl SimdKernels for NeonKernels {
    fn name(&self) -> &'static str {
        "neon"
    }

    fn dot(&self, x: &[f64], y: &[f64]) -> f64 {
        debug_assert_eq!(x.len(), y.len());
        // SAFETY: NEON is baseline on aarch64
        unsafe { dot_neon(x, y) }
    }

    fn axpy(&self, a: f64, x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(x.len(), y.len());
        // SAFETY: as above
        unsafe { axpy_neon(a, x, y) }
    }
}

unsafe fn dot_neon(x: &[f64], y: &[f64]) -> f64 {
    let n = x.len();
    let chunks = n / 4;
    let xp = x.as_ptr();
    let yp = y.as_ptr();
    let mut acc01 = vdupq_n_f64(0.0);
    let mut acc23 = vdupq_n_f64(0.0);
    for i in 0..chunks {
        let o = i * 4;
        // mul then add (no fused multiply-add): lane k is exactly the
        // scalar s_k accumulator
        acc01 = vaddq_f64(
            acc01,
            vmulq_f64(vld1q_f64(xp.add(o)), vld1q_f64(yp.add(o))),
        );
        acc23 = vaddq_f64(
            acc23,
            vmulq_f64(vld1q_f64(xp.add(o + 2)), vld1q_f64(yp.add(o + 2))),
        );
    }
    let mut s = (vgetq_lane_f64::<0>(acc01) + vgetq_lane_f64::<1>(acc01))
        + (vgetq_lane_f64::<0>(acc23) + vgetq_lane_f64::<1>(acc23));
    for i in chunks * 4..n {
        s += *xp.add(i) * *yp.add(i);
    }
    s
}

unsafe fn axpy_neon(a: f64, x: &[f64], y: &mut [f64]) {
    let n = x.len();
    let chunks = n / 2;
    let va = vdupq_n_f64(a);
    let xp = x.as_ptr();
    let yp = y.as_mut_ptr();
    for i in 0..chunks {
        let o = i * 2;
        let vx = vld1q_f64(xp.add(o));
        let vy = vld1q_f64(yp.add(o));
        vst1q_f64(yp.add(o), vaddq_f64(vy, vmulq_f64(va, vx)));
    }
    for i in chunks * 2..n {
        *yp.add(i) += a * *xp.add(i);
    }
}
