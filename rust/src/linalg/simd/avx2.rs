//! AVX2 (256-bit, 4 × f64) kernel backend — x86_64 only.
//!
//! Every kernel reproduces the scalar reference's exact operation
//! sequence: one vector lane per scalar unroll slot, separate
//! multiply and add (intrinsics are never FMA-contracted), and the
//! same `(s0+s1)+(s2+s3)` reduction — so results are bit-identical to
//! [`super::scalar`], which `tests/simd_equivalence.rs` pins.
//!
//! Safety: the `#[target_feature(enable = "avx2")]` functions are
//! only reachable through [`Avx2Kernels`], and the dispatch layer
//! only hands that table out after `is_x86_feature_detected!("avx2")`
//! succeeded.

use core::arch::x86_64::*;

use super::{scalar, SimdKernels};

/// The AVX2 kernel table (constructed by the dispatcher after runtime
/// feature detection).
pub struct Avx2Kernels;

impl SimdKernels for Avx2Kernels {
    fn name(&self) -> &'static str {
        "avx2"
    }

    fn dot(&self, x: &[f64], y: &[f64]) -> f64 {
        debug_assert_eq!(x.len(), y.len());
        // SAFETY: table handed out only after avx2 detection
        unsafe { dot_avx2(x, y) }
    }

    fn axpy(&self, a: f64, x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(x.len(), y.len());
        // SAFETY: as above
        unsafe { axpy_avx2(a, x, y) }
    }

    fn cvt_f64_to_f32_bits(&self, src: &[f64], dst: &mut [u32]) {
        debug_assert_eq!(src.len(), dst.len());
        // SAFETY: as above
        unsafe { cvt_f64_to_f32_bits_avx2(src, dst) }
    }

    fn cvt_f32_bits_axpy(&self, a: f64, bits: &[u32], y: &mut [f64]) {
        debug_assert_eq!(bits.len(), y.len());
        // SAFETY: as above
        unsafe { cvt_f32_bits_axpy_avx2(a, bits, y) }
    }

    fn quantize_clamped(
        &self,
        src: &[f64],
        inv_scale: f64,
        levels: f64,
        out: &mut [f64],
    ) {
        debug_assert_eq!(src.len(), out.len());
        // SAFETY: as above
        unsafe { quantize_clamped_avx2(src, inv_scale, levels, out) }
    }
}

#[target_feature(enable = "avx2")]
unsafe fn dot_avx2(x: &[f64], y: &[f64]) -> f64 {
    let n = x.len();
    let chunks = n / 4;
    let xp = x.as_ptr();
    let yp = y.as_ptr();
    let mut acc = _mm256_setzero_pd();
    for i in 0..chunks {
        let o = i * 4;
        let a = _mm256_loadu_pd(xp.add(o));
        let b = _mm256_loadu_pd(yp.add(o));
        // mul then add — lane j is exactly the scalar s_j accumulator
        acc = _mm256_add_pd(acc, _mm256_mul_pd(a, b));
    }
    let mut lanes = [0.0f64; 4];
    _mm256_storeu_pd(lanes.as_mut_ptr(), acc);
    let mut s = (lanes[0] + lanes[1]) + (lanes[2] + lanes[3]);
    for i in chunks * 4..n {
        s += *xp.add(i) * *yp.add(i);
    }
    s
}

#[target_feature(enable = "avx2")]
unsafe fn axpy_avx2(a: f64, x: &[f64], y: &mut [f64]) {
    let n = x.len();
    let chunks = n / 4;
    let va = _mm256_set1_pd(a);
    let xp = x.as_ptr();
    let yp = y.as_mut_ptr();
    for i in 0..chunks {
        let o = i * 4;
        let vx = _mm256_loadu_pd(xp.add(o));
        let vy = _mm256_loadu_pd(yp.add(o));
        _mm256_storeu_pd(yp.add(o), _mm256_add_pd(vy, _mm256_mul_pd(va, vx)));
    }
    for i in chunks * 4..n {
        *yp.add(i) += a * *xp.add(i);
    }
}

#[target_feature(enable = "avx2")]
unsafe fn cvt_f64_to_f32_bits_avx2(src: &[f64], dst: &mut [u32]) {
    let n = src.len();
    let chunks = n / 4;
    let sp = src.as_ptr();
    let dp = dst.as_mut_ptr();
    for i in 0..chunks {
        let o = i * 4;
        // hardware narrowing convert uses the same round-to-nearest-
        // even as Rust's `as f32`
        let f = _mm256_cvtpd_ps(_mm256_loadu_pd(sp.add(o)));
        _mm_storeu_ps(dp.add(o) as *mut f32, f);
    }
    for i in chunks * 4..n {
        *dp.add(i) = (*sp.add(i) as f32).to_bits();
    }
}

#[target_feature(enable = "avx2")]
unsafe fn cvt_f32_bits_axpy_avx2(a: f64, bits: &[u32], y: &mut [f64]) {
    let n = bits.len();
    let chunks = n / 4;
    let va = _mm256_set1_pd(a);
    let bp = bits.as_ptr();
    let yp = y.as_mut_ptr();
    for i in 0..chunks {
        let o = i * 4;
        // widening convert is exact, so this matches the scalar
        // f32 → f64 promotion bit for bit
        let v = _mm256_cvtps_pd(_mm_loadu_ps(bp.add(o) as *const f32));
        let vy = _mm256_loadu_pd(yp.add(o));
        _mm256_storeu_pd(yp.add(o), _mm256_add_pd(vy, _mm256_mul_pd(va, v)));
    }
    for i in chunks * 4..n {
        *yp.add(i) += a * f64::from(f32::from_bits(*bp.add(i)));
    }
}

#[target_feature(enable = "avx2")]
unsafe fn quantize_clamped_avx2(
    src: &[f64],
    inv_scale: f64,
    levels: f64,
    out: &mut [f64],
) {
    let n = src.len();
    let chunks = n / 4;
    let vs = _mm256_set1_pd(inv_scale);
    let vhalf = _mm256_set1_pd(0.5);
    let vsign = _mm256_set1_pd(-0.0);
    let vlo = _mm256_set1_pd(-levels);
    let vhi = _mm256_set1_pd(levels);
    let sp = src.as_ptr();
    let op = out.as_mut_ptr();
    for i in 0..chunks {
        let o = i * 4;
        let t = _mm256_mul_pd(_mm256_loadu_pd(sp.add(o)), vs);
        // copysign(0.5, t) as pure bit ops — identical to the scalar
        let h = _mm256_or_pd(_mm256_and_pd(vsign, t), vhalf);
        let r = _mm256_round_pd::<{ _MM_FROUND_TO_ZERO | _MM_FROUND_NO_EXC }>(
            _mm256_add_pd(t, h),
        );
        // maxpd/minpd resolve NaN and ties to the second operand —
        // the semantics scalar::quantize_one spells out
        let q = _mm256_min_pd(vhi, _mm256_max_pd(vlo, r));
        _mm256_storeu_pd(op.add(o), q);
    }
    for i in chunks * 4..n {
        *op.add(i) = scalar::quantize_one(*sp.add(i), inv_scale, levels);
    }
}
