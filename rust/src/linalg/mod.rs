//! Dense linear algebra substrate (BLAS-free, f64).
//!
//! This is the compute layer of the pure-rust gradient backend
//! (`tasks/`), mirroring the L1 Pallas kernels: the same fused
//! residual-gradient passes, expressed as cache-blocked loops instead
//! of VMEM tiles.  All hot-path functions are allocation-free (writes
//! go into caller-provided buffers) so the coordinator's steady-state
//! round performs zero heap allocation — see EXPERIMENTS.md §Perf.

pub mod cholesky;
pub mod matrix;
pub mod simd;

pub use cholesky::Cholesky;
pub use matrix::Matrix;

/// x·y — runtime-dispatched to the active [`simd`] backend; every
/// backend reproduces the scalar reference's fixed 4-way association
/// order, so the result is deterministic and backend-independent.
#[inline]
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    simd::kernels().dot(x, y)
}

/// ‖x‖²
#[inline]
pub fn norm2_sq(x: &[f64]) -> f64 {
    dot(x, x)
}

/// ‖x‖₂
#[inline]
pub fn norm2(x: &[f64]) -> f64 {
    norm2_sq(x).sqrt()
}

/// ‖x − y‖²
#[inline]
pub fn dist2_sq(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    let mut s = 0.0;
    for i in 0..x.len() {
        let d = x[i] - y[i];
        s += d * d;
    }
    s
}

/// y ← y + a·x (runtime-dispatched to the active [`simd`] backend;
/// element-wise, so every lane width is bit-identical).
#[inline]
pub fn axpy(a: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    simd::kernels().axpy(a, x, y)
}

/// y[idx[j]] ← y[idx[j]] + a·val[j] — the sparse fold primitive.
///
/// O(nnz) instead of O(d): this is what lets the server fold a top-k
/// payload without ever materializing the dense decode.  Each stored
/// coordinate touches `y` exactly once, so the result matches a dense
/// `axpy` over the decoded vector bit for bit on every stored
/// coordinate; untouched coordinates are left alone instead of having
/// an explicit 0.0 added (identical values — the only representational
/// difference is that a −0.0 in `y` keeps its sign).
#[inline]
pub fn axpy_sparse(a: f64, idx: &[u32], val: &[f64], y: &mut [f64]) {
    debug_assert_eq!(idx.len(), val.len());
    simd::kernels().axpy_sparse(a, idx, val, y)
}

/// out ← x − y
#[inline]
pub fn sub_into(x: &[f64], y: &[f64], out: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    debug_assert_eq!(x.len(), out.len());
    for i in 0..x.len() {
        out[i] = x[i] - y[i];
    }
}

/// x ← a·x
#[inline]
pub fn scale(a: f64, x: &mut [f64]) {
    for v in x.iter_mut() {
        *v *= a;
    }
}

/// Σ|x_i|
#[inline]
pub fn norm1(x: &[f64]) -> f64 {
    x.iter().map(|v| v.abs()).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let x: Vec<f64> = (0..103).map(|i| i as f64 * 0.5).collect();
        let y: Vec<f64> = (0..103).map(|i| (103 - i) as f64).collect();
        let naive: f64 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        assert!((dot(&x, &y) - naive).abs() < 1e-9 * naive.abs());
    }

    #[test]
    fn dot_handles_short_and_empty() {
        assert_eq!(dot(&[], &[]), 0.0);
        assert_eq!(dot(&[2.0], &[3.0]), 6.0);
        assert_eq!(dot(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]), 32.0);
    }

    #[test]
    fn axpy_sparse_matches_dense_axpy_bitwise() {
        let decoded = vec![0.0, -5.0, 0.0, 3.0, 0.0];
        let idx = vec![1u32, 3];
        let val = vec![-5.0, 3.0];
        let mut dense = vec![0.25, -1.5, 7.0, 0.125, -3.0];
        let mut sparse = dense.clone();
        axpy(1.0, &decoded, &mut dense);
        axpy_sparse(1.0, &idx, &val, &mut sparse);
        for (a, b) in dense.iter().zip(&sparse) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // empty payload is a no-op
        axpy_sparse(2.0, &[], &[], &mut sparse);
        for (a, b) in dense.iter().zip(&sparse) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn axpy_and_sub() {
        let x = vec![1.0, 2.0, 3.0];
        let mut y = vec![10.0, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![12.0, 24.0, 36.0]);
        let mut out = vec![0.0; 3];
        sub_into(&y, &x, &mut out);
        assert_eq!(out, vec![11.0, 22.0, 33.0]);
    }

    #[test]
    fn norms() {
        let x = vec![3.0, -4.0];
        assert_eq!(norm2_sq(&x), 25.0);
        assert_eq!(norm2(&x), 5.0);
        assert_eq!(norm1(&x), 7.0);
        assert_eq!(dist2_sq(&x, &[0.0, 0.0]), 25.0);
    }
}
